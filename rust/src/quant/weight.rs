//! FGQ (fine-grained group-wise) weight quantization and the quantized
//! weight container.
//!
//! Weights are stored `[out_features, in_features]` (row = output channel).
//! FGQ assigns one scale per `(row, column-group)` where a column group is
//! `group_size` consecutive input dims — the paper uses group 256 (320 for
//! LLaMA-3b). `group_size == 0` means one group per row (per-channel).
//!
//! The container stores true low-bit *codes* (not just dequantized floats)
//! so model-size accounting, bit-shift casting, and the PJRT kernel path all
//! operate on the real representation.

use crate::formats::{FpFormat, GroupParams, NumericFormat};
use crate::tensor::Matrix;

use super::constraints::{constrain_scales, ScaleConstraint};

/// Configuration for weight quantization.
#[derive(Debug, Clone, Copy)]
pub struct WeightQuantConfig {
    /// Target format (INT4/INT8/FP4/FP8 or F16 passthrough).
    pub format: NumericFormat,
    /// FGQ group size along the input dimension (0 = whole row).
    pub group_size: usize,
    /// Power-of-2 scale constraint (Section 3 "Casting the FP4 to FP8").
    pub constraint: ScaleConstraint,
    /// Footnote 4: once a matrix is quantized to FP4, re-quantize the
    /// dequantized values to FP8 E5M2 so the runtime weight is exactly an
    /// FP8 number (the H100 cast path). Applied by `dequantize`.
    pub cast_fp4_to_e5m2: bool,
}

impl WeightQuantConfig {
    pub fn new(format: NumericFormat) -> Self {
        WeightQuantConfig {
            format,
            group_size: 256,
            constraint: ScaleConstraint::None,
            cast_fp4_to_e5m2: false,
        }
    }

    pub fn with_group_size(mut self, g: usize) -> Self {
        self.group_size = g;
        self
    }

    pub fn with_constraint(mut self, c: ScaleConstraint) -> Self {
        self.constraint = c;
        self
    }

    pub fn with_cast(mut self, cast: bool) -> Self {
        self.cast_fp4_to_e5m2 = cast;
        self
    }

    /// Effective group size for a row length.
    pub fn group_for(&self, cols: usize) -> usize {
        if self.group_size == 0 || self.group_size > cols {
            cols
        } else {
            self.group_size
        }
    }
}

/// A quantized weight matrix: codes + per-(row, group) parameters.
#[derive(Debug, Clone)]
pub struct QuantizedWeight {
    pub rows: usize,
    pub cols: usize,
    pub group_size: usize,
    pub format: NumericFormat,
    /// One code per weight. FP codes are the ExMy bit pattern; INT codes are
    /// the signed level offset-encoded as `level + 128`.
    pub codes: Vec<u8>,
    /// `rows * n_groups` scales, row-major.
    pub scales: Vec<f32>,
    /// Zero points (INT asymmetric only; empty otherwise).
    pub zeros: Vec<i32>,
    /// Whether dequantization re-quantizes to FP8 E5M2 (footnote 4 cast).
    pub cast_fp4_to_e5m2: bool,
    /// The scale constraint the scales were projected under — recorded so
    /// the packed execution path ([`crate::quant::PackedWeight`]) can plan
    /// shift-dequant against the M1/M2 structure without re-deriving it.
    pub constraint: ScaleConstraint,
}

impl QuantizedWeight {
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    #[inline]
    pub fn scale_at(&self, row: usize, col: usize) -> f32 {
        self.scales[row * self.n_groups() + col / self.group_size]
    }

    /// Serialized size in bytes of the quantized representation
    /// (codes at true bit-width + one f16 scale (+ i8 zero) per group).
    pub fn packed_bytes(&self) -> usize {
        let code_bits = self.format.bits() as usize * self.rows * self.cols;
        let scale_bytes = 2 * self.scales.len();
        let zero_bytes = self.zeros.len();
        code_bits.div_ceil(8) + scale_bytes + zero_bytes
    }

    /// Dequantize a single element.
    #[inline]
    pub fn dequant_at(&self, row: usize, col: usize) -> f32 {
        let ng = self.n_groups();
        let g = row * ng + col / self.group_size;
        let code = self.codes[row * self.cols + col];
        let scale = self.scales[g];
        let v = match self.format {
            NumericFormat::F16 => unreachable!("F16 weights are not stored quantized"),
            NumericFormat::Fp(f) => f.decode(code as u16) * scale,
            NumericFormat::Int(i) => {
                let z = if i.symmetric { 0 } else { self.zeros[g] };
                (code as i32 - 128 - z) as f32 * scale
            }
        };
        if self.cast_fp4_to_e5m2 {
            FpFormat::E5M2.quantize(v)
        } else {
            v
        }
    }

    /// Dequantize the whole matrix to f32.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let ng = self.n_groups();
        for r in 0..self.rows {
            for g in 0..ng {
                let scale = self.scales[r * ng + g];
                let zero = if self.zeros.is_empty() { 0 } else { self.zeros[r * ng + g] };
                let c0 = g * self.group_size;
                let c1 = (c0 + self.group_size).min(self.cols);
                for c in c0..c1 {
                    let code = self.codes[r * self.cols + c];
                    let v = match self.format {
                        NumericFormat::F16 => unreachable!(),
                        NumericFormat::Fp(f) => f.decode(code as u16) * scale,
                        NumericFormat::Int(i) => {
                            let _ = i;
                            (code as i32 - 128 - zero) as f32 * scale
                        }
                    };
                    out.data[r * self.cols + c] = if self.cast_fp4_to_e5m2 {
                        FpFormat::E5M2.quantize(v)
                    } else {
                        v
                    };
                }
            }
        }
        out
    }

    /// Quantization error matrix `W - dequant(Q(W))`.
    pub fn error_vs(&self, w: &Matrix) -> Matrix {
        w.sub(&self.dequantize())
    }
}

/// Encode one value under (format, params); returns (code, dequant value).
#[inline]
pub fn encode_value(format: NumericFormat, x: f32, p: GroupParams) -> (u8, f32) {
    match format {
        NumericFormat::F16 => (0, x),
        NumericFormat::Fp(f) => {
            let code = f.encode(x / p.scale);
            (code as u8, f.decode(code) * p.scale)
        }
        NumericFormat::Int(i) => {
            let ip = crate::formats::IntQParams { scale: p.scale, zero_point: p.zero_point };
            let level = i.encode(x, ip);
            let stored = if i.symmetric { level } else { level - p.zero_point };
            ((stored + 128) as u8, i.decode(level, ip))
        }
    }
}

/// Round-to-nearest (RTN) FGQ quantization of a weight matrix — the
/// non-GPTQ baseline, also used to initialize scales for GPTQ.
pub fn quantize_weight_rtn(w: &Matrix, cfg: &WeightQuantConfig) -> QuantizedWeight {
    let group = cfg.group_for(w.cols);
    let ng = w.cols.div_ceil(group);
    let mut scales = vec![1.0f32; w.rows * ng];
    let mut zeros_v: Vec<i32> = Vec::new();
    let asym = matches!(cfg.format, NumericFormat::Int(i) if !i.symmetric);
    if asym {
        zeros_v = vec![0i32; w.rows * ng];
    }
    // Pass 1: group params.
    for r in 0..w.rows {
        let row = w.row(r);
        for g in 0..ng {
            let c0 = g * group;
            let c1 = (c0 + group).min(w.cols);
            let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in &row[c0..c1] {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            let p = cfg.format.group_params(mn, mx);
            scales[r * ng + g] = p.scale;
            if asym {
                zeros_v[r * ng + g] = p.zero_point;
            }
        }
    }
    // Scale constraint projection (power-of-2 methods M1/M2).
    constrain_scales(&mut scales, w.rows, ng, cfg.constraint);
    // Pass 2: encode with the (possibly constrained) scales.
    let mut codes = vec![0u8; w.rows * w.cols];
    for r in 0..w.rows {
        for g in 0..ng {
            let p = GroupParams {
                scale: scales[r * ng + g],
                zero_point: if asym { zeros_v[r * ng + g] } else { 0 },
            };
            let c0 = g * group;
            let c1 = (c0 + group).min(w.cols);
            for c in c0..c1 {
                let (code, _) = encode_value(cfg.format, w.at(r, c), p);
                codes[r * w.cols + c] = code;
            }
        }
    }
    QuantizedWeight {
        rows: w.rows,
        cols: w.cols,
        group_size: group,
        format: cfg.format,
        codes,
        scales,
        zeros: zeros_v,
        cast_fp4_to_e5m2: cfg.cast_fp4_to_e5m2 && matches!(cfg.format, NumericFormat::Fp(f) if f.total_bits() == 4),
        constraint: cfg.constraint,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn rtn_roundtrip_error_bounded() {
        let mut rng = Rng::seeded(41);
        let w = Matrix::randn(32, 128, 0.05, &mut rng);
        for fmt in [
            NumericFormat::INT8,
            NumericFormat::FP8_E4M3,
            NumericFormat::INT4,
            NumericFormat::FP4_E2M1,
        ] {
            let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(fmt).with_group_size(64));
            let deq = q.dequantize();
            let rel = deq.sub(&w).fro_norm() / w.fro_norm();
            // INT grids are uniform (tight near zero); FP grids are relative
            // (coarser near absmax). RMS bounds per family, Gaussian data:
            let bound = match fmt {
                NumericFormat::INT8 => 0.012,
                NumericFormat::FP8_E4M3 => 0.04,
                _ => 0.15, // 4-bit
            };
            assert!(rel < bound, "{}: rel={rel}", fmt.name());
        }
    }

    #[test]
    fn eight_bit_beats_four_bit() {
        let mut rng = Rng::seeded(42);
        let w = Matrix::randn(16, 256, 0.02, &mut rng);
        let q8 = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP8_E4M3));
        let q4 = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        assert!(q8.dequantize().mse(&w) < q4.dequantize().mse(&w));
    }

    #[test]
    fn smaller_groups_reduce_error() {
        let mut rng = Rng::seeded(43);
        // heavy-tailed rows: per-row absmax dominated by outliers
        let mut w = Matrix::randn(8, 512, 0.02, &mut rng);
        for r in 0..8 {
            w.row_mut(r)[r * 7] = 1.0; // a few outliers
        }
        let big = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::INT4).with_group_size(0),
        );
        let small = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::INT4).with_group_size(64),
        );
        assert!(small.dequantize().mse(&w) < big.dequantize().mse(&w));
    }

    #[test]
    fn dequant_at_matches_dequantize() {
        let mut rng = Rng::seeded(44);
        let w = Matrix::randn(9, 130, 0.1, &mut rng); // ragged last group
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(64),
        );
        let full = q.dequantize();
        for r in 0..9 {
            for c in 0..130 {
                assert_eq!(q.dequant_at(r, c), full.at(r, c));
            }
        }
    }

    #[test]
    fn asymmetric_int_roundtrip() {
        let mut rng = Rng::seeded(45);
        // shifted distribution favours asym
        let w = Matrix::from_fn(8, 64, |_, _| rng.normal_f32() * 0.02 + 0.1);
        let qa = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::INT4_ASYM));
        let qs = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::INT4));
        assert!(qa.dequantize().mse(&w) < qs.dequantize().mse(&w));
    }

    #[test]
    fn cast_policy_makes_values_e5m2() {
        let mut rng = Rng::seeded(46);
        let w = Matrix::randn(4, 64, 0.1, &mut rng);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_cast(true),
        );
        let deq = q.dequantize();
        for &v in &deq.data {
            assert_eq!(FpFormat::E5M2.quantize(v), v, "value {v} not an E5M2 point");
        }
    }

    #[test]
    fn packed_bytes_accounting() {
        let w = Matrix::zeros(16, 256);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(64),
        );
        // 16*256 4-bit codes = 2048 bytes; 16*4 scales * 2B = 128
        assert_eq!(q.packed_bytes(), 2048 + 128);
    }
}
