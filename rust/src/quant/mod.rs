//! The quantization stack: weight FGQ (fine-grained group-wise) quantization,
//! token-wise activation quantization, power-of-2 scale constraints (M1/M2),
//! the FP4→FP8 cast policy, and true bit-packed weight storage with
//! shift-dequant planning ([`packed`]) — i.e. everything Section 3 of
//! ZeroQuant-FP describes apart from GPTQ itself (see [`crate::gptq`]) and
//! LoRC (see [`crate::lorc`]).

pub mod activation;
pub mod constraints;
pub mod packed;
pub mod weight;

pub use activation::{fake_quant_tokenwise, ActQuantConfig};
pub use constraints::{constrain_scales, is_pow2, next_pow2, ScaleConstraint};
pub use packed::{PackedWeight, QuantSidecar, SidecarEntry};
pub use weight::{encode_value, quantize_weight_rtn, QuantizedWeight, WeightQuantConfig};

use crate::formats::NumericFormat;

/// A full W·A precision scheme, e.g. "W4A8 FP-FP" from Table 2's rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scheme {
    pub weight: NumericFormat,
    pub activation: NumericFormat,
}

impl Scheme {
    pub const W16A16: Scheme = Scheme {
        weight: NumericFormat::F16,
        activation: NumericFormat::F16,
    };

    /// Parse paper-style scheme names: "w8a8-int-int", "w4a8-fp-fp",
    /// "w4a8-int-fp", "w16a16", "w16a8-int" …
    pub fn parse(s: &str) -> Option<Scheme> {
        let t = s.to_ascii_lowercase();
        let parts: Vec<&str> = t.split('-').collect();
        let wa = parts[0];
        let (wbits, abits) = match wa {
            "w16a16" => (16u32, 16u32),
            "w16a8" => (16, 8),
            "w8a8" => (8, 8),
            "w4a8" => (4, 8),
            "w4a16" => (4, 16),
            "w8a16" => (8, 16),
            _ => return None,
        };
        let wkind = parts.get(1).copied().unwrap_or("int");
        let akind = parts.get(2).copied().unwrap_or(wkind);
        let weight = match (wbits, wkind) {
            (16, _) => NumericFormat::F16,
            (8, "int") => NumericFormat::INT8,
            (8, "fp") => NumericFormat::FP8_E4M3,
            (4, "int") => NumericFormat::INT4,
            (4, "fp") => NumericFormat::FP4_E2M1,
            (4, "fpe3m0") => NumericFormat::FP4_E3M0,
            _ => return None,
        };
        let activation = match (abits, akind) {
            (16, _) => NumericFormat::F16,
            (8, "int") => NumericFormat::INT8,
            (8, "fp") => NumericFormat::FP8_E4M3,
            _ => return None,
        };
        Some(Scheme { weight, activation })
    }

    pub fn name(&self) -> String {
        let wb = self.weight.bits();
        let ab = self.activation.bits();
        let kind = |f: &NumericFormat| {
            if matches!(f, NumericFormat::F16) {
                "-"
            } else if f.is_fp() {
                "FP"
            } else {
                "INT"
            }
        };
        format!(
            "W{}A{} {}-{}",
            wb,
            ab,
            kind(&self.weight),
            kind(&self.activation)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        let s = Scheme::parse("w4a8-fp-fp").unwrap();
        assert_eq!(s.weight, NumericFormat::FP4_E2M1);
        assert_eq!(s.activation, NumericFormat::FP8_E4M3);

        let s = Scheme::parse("w8a8-int-fp").unwrap();
        assert_eq!(s.weight, NumericFormat::INT8);
        assert_eq!(s.activation, NumericFormat::FP8_E4M3);

        let s = Scheme::parse("w16a8-int").unwrap();
        assert_eq!(s.weight, NumericFormat::F16);
        assert_eq!(s.activation, NumericFormat::INT8);

        assert_eq!(Scheme::parse("w16a16").unwrap(), Scheme::W16A16);
        assert!(Scheme::parse("w2a2").is_none());
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::parse("w4a8-int-fp").unwrap().name(), "W4A8 INT-FP");
        assert_eq!(Scheme::W16A16.name(), "W16A16 ---");
    }
}
