//! Chaos suite: the serving loop under deterministic fault injection.
//!
//! The contract under test (ISSUE 6's tentpole invariant): under *any*
//! fault schedule every client gets exactly one typed response
//! (`Ok`/`Overloaded`/`DeadlineExceeded`/`Faulted`/`ShuttingDown`), the
//! loop never hangs (every run here is bounded by a watchdog timeout),
//! poisoned KV caches are quarantined instead of recycled, and the
//! sequences a fault did *not* touch finish bit-identical to the dense
//! reference — panic isolation must not perturb surviving traffic.
//!
//! Fault schedules are seeded (xoshiro-backed `FaultPlan::with_seed`),
//! so every run of this suite replays the exact same faults.

use std::sync::mpsc::sync_channel;
use std::sync::Once;
use std::time::{Duration, Instant};

use zeroquant_fp::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, FaultPayload, FaultPlan, Generated,
    SamplingConfig, ScoreBackend, ServeError, ServeReport, ServingStack, DEFAULT_MAX_SESSIONS,
};
use zeroquant_fp::engine::{EngineOpts, KernelTier};
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::{argmax, CompiledModel};
use zeroquant_fp::quant::Scheme;
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

/// Silence the default panic printout for *injected* panics (they are
/// the point of this suite); genuine panics still print. Installed once
/// per test binary.
fn quiet_injected_panics() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<FaultPayload>().is_none() {
                prev(info);
            }
        }));
    });
}

fn tiny_ck() -> Checkpoint {
    let cfg = ModelConfig {
        name: "chaos-test".into(),
        arch: Arch::Opt,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 16,
    };
    let mut rng = Rng::seeded(4242);
    Checkpoint::random(&cfg, &mut rng)
}

fn cfg_with(ck: Checkpoint, max_batch: usize, faults: Option<FaultPlan>) -> CoordinatorConfig {
    CoordinatorConfig {
        backend: ScoreBackend::Compiled,
        ck,
        opts: EngineOpts::default(),
        policy: BatchPolicy { max_batch, max_wait: Duration::ZERO },
        kv_quant: None,
        sidecar: None,
        queue_depth: 64,
        deadline: None,
        faults,
        speculate: None,
        kv_page_positions: 0,
        kv_budget_bytes: 0,
        sampling: SamplingConfig::default(),
        max_sessions: DEFAULT_MAX_SESSIONS,
    }
}

/// Run the serving loop on its own thread with a watchdog: a loop that
/// hangs under a fault schedule fails the suite instead of wedging it.
fn run_within(coord: Coordinator, secs: u64) -> ServeReport {
    let (tx, rx) = sync_channel(1);
    let h = std::thread::spawn(move || {
        let _ = tx.send(coord.run());
    });
    let report = rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("serving loop must terminate within the watchdog timeout")
        .expect("serving loop must return a report, not an error");
    h.join().unwrap();
    report
}

/// Greedy reference decode straight through the compiled plan — what an
/// unfaulted coordinator generation must match bit for bit.
fn greedy_reference(model: &CompiledModel, prompt: &[u16], max_new: usize) -> Vec<u16> {
    let mut scratch = model.scratch();
    let mut cache = model.kv_cache();
    let mut out = Vec::with_capacity(max_new);
    let logits = model.prefill(prompt, &mut cache, &mut scratch);
    let mut tok = argmax(logits.row(prompt.len() - 1)) as u16;
    out.push(tok);
    for _ in 1..max_new {
        let logits = model.decode_step(tok, &mut cache, &mut scratch);
        tok = argmax(logits.row(0)) as u16;
        out.push(tok);
    }
    out
}

fn prompt_for(client: usize, i: usize) -> Vec<u16> {
    (0..5).map(|k| ((client * 17 + i * 5 + k * 3) % 48) as u16).collect()
}

/// The headline chaos drill: probabilistic faults at all four sites,
/// replayed over ≥4 fixed seeds. Every submission gets exactly one typed
/// response, the loop terminates, the books balance, and every `Ok` that
/// made it through is bit-identical to the dense reference.
#[test]
fn chaos_every_client_gets_exactly_one_typed_response() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    let mut ref_scratch = reference.scratch();
    let seq = ck.config.max_seq;
    let mut wrng = Rng::seeded(7);
    let windows: Vec<Vec<u16>> =
        (0..4).map(|_| (0..seq).map(|_| wrng.below(48) as u16).collect()).collect();
    let ref_nll: Vec<f32> =
        windows.iter().map(|w| reference.score_nll(w, &mut ref_scratch)).collect();

    let mut total_degraded = 0usize;
    for seed in [101u64, 202, 303, 404] {
        let plan = FaultPlan::parse("admission:p=0.25,prefill:p=0.25,decode:p=0.15,respond:p=0.2")
            .unwrap()
            .with_seed(seed);
        let coord = Coordinator::new(cfg_with(ck.clone(), 4, Some(plan)));

        let mut score_handles = Vec::new();
        for _ in 0..3usize {
            let client = coord.client().unwrap();
            let mine = windows.clone();
            score_handles.push(std::thread::spawn(move || {
                mine.into_iter().map(|w| client.score(w)).collect::<Vec<_>>()
            }));
        }
        let mut gen_handles = Vec::new();
        for c in 0..3usize {
            let client = coord.gen_client().unwrap();
            gen_handles.push(std::thread::spawn(move || {
                (0..3)
                    .map(|i| {
                        let p = prompt_for(c, i);
                        (p.clone(), client.generate(p, 4))
                    })
                    .collect::<Vec<_>>()
            }));
        }

        let report = run_within(coord, 30);

        let mut submissions = 0usize;
        let mut responses = 0usize;
        for h in score_handles {
            for (i, res) in h.join().unwrap().into_iter().enumerate() {
                submissions += 1;
                responses += 1;
                match res {
                    Ok(nll) => assert_eq!(
                        nll.to_bits(),
                        ref_nll[i].to_bits(),
                        "seed {seed}: surviving score must be bit-identical"
                    ),
                    Err(ServeError::Overloaded)
                    | Err(ServeError::Faulted(_))
                    | Err(ServeError::DeadlineExceeded { .. })
                    | Err(ServeError::ShuttingDown) => total_degraded += 1,
                    Err(other) => panic!("seed {seed}: untyped score failure {other:?}"),
                }
            }
        }
        for h in gen_handles {
            for (prompt, res) in h.join().unwrap() {
                submissions += 1;
                responses += 1;
                match res {
                    Ok(Generated { tokens, prompt_len, .. }) => {
                        assert_eq!(prompt_len, prompt.len());
                        assert_eq!(
                            tokens,
                            greedy_reference(&reference, &prompt, 4),
                            "seed {seed}: surviving generation must be bit-identical"
                        );
                    }
                    Err(ServeError::Overloaded)
                    | Err(ServeError::Faulted(_))
                    | Err(ServeError::DeadlineExceeded { .. })
                    | Err(ServeError::ShuttingDown) => total_degraded += 1,
                    Err(other) => panic!("seed {seed}: untyped gen failure {other:?}"),
                }
            }
        }
        assert_eq!(responses, submissions, "exactly one response per submission");
        // every submission is accounted for: either it reached the loop
        // (requests) or it was shed at the bounded queue
        assert_eq!(
            report.requests + report.shed_overloaded,
            submissions,
            "seed {seed}: the books must balance"
        );
        assert!(report.faulted + report.expired_admission <= report.requests);
    }
    // across four seeds of p≥0.15 faults over ~84 requests, at least one
    // fault must have tripped (deterministic given the fixed seeds)
    assert!(total_degraded > 0, "the chaos schedules never tripped a fault");
}

/// `site:always` at each site: every generation answers typed `Faulted`
/// naming the site, the loop survives, and caches are quarantined
/// exactly when a panic unwound out of a layer walk (prefill/decode) —
/// never for faults outside the plan (admission/respond).
#[test]
fn always_fault_at_each_site_answers_typed_and_quarantines() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let n = 3usize;
    for (site, expect_quarantined, expect_gen_started) in [
        ("admission", 0usize, false),
        ("prefill", n, true),
        ("decode", n, true),
        ("respond", 0, true),
    ] {
        let plan = FaultPlan::parse(&format!("{site}:always")).unwrap();
        let coord = Coordinator::new(cfg_with(ck.clone(), 4, Some(plan)));
        let mut handles = Vec::new();
        for c in 0..n {
            let client = coord.gen_client().unwrap();
            handles.push(std::thread::spawn(move || client.generate(prompt_for(c, 0), 3)));
        }
        let report = run_within(coord, 30);
        for h in handles {
            match h.join().unwrap() {
                Err(ServeError::Faulted(msg)) => assert!(
                    msg.contains(site),
                    "{site}: fault message should name its site, got {msg:?}"
                ),
                other => panic!("{site}:always must answer Faulted, got {other:?}"),
            }
        }
        assert_eq!(report.requests, n, "{site}");
        assert_eq!(report.faulted, n, "{site}: every response Faulted");
        assert_eq!(
            report.quarantined_caches, expect_quarantined,
            "{site}: quarantine exactly the caches a panic touched"
        );
        assert_eq!(report.gen_requests > 0, expect_gen_started, "{site}");
    }
}

/// A batched decode step panics once (`decode:nth=2`); the solo retry
/// replays the step for every sequence. Nothing faults outward, nothing
/// is quarantined, and every generation still matches the reference bit
/// for bit — the KV cursors only commit at the end of an unwound-free
/// layer walk, so the retry is exact.
#[test]
fn survivors_bit_identical_after_batch_decode_panic() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    let plan = FaultPlan::parse("decode:nth=2").unwrap();
    let coord = Coordinator::new(cfg_with(ck.clone(), 4, Some(plan)));
    let mut handles = Vec::new();
    for c in 0..3usize {
        let client = coord.gen_client().unwrap();
        handles.push(std::thread::spawn(move || {
            let p = prompt_for(c, 1);
            (p.clone(), client.generate(p, 4))
        }));
    }
    let report = run_within(coord, 30);
    for h in handles {
        let (prompt, res) = h.join().unwrap();
        let got = res.expect("a retried sequence must still succeed");
        assert_eq!(got.tokens, greedy_reference(&reference, &prompt, 4));
    }
    assert_eq!(report.gen_requests, 3);
    assert_eq!(report.faulted, 0, "the retry absorbed the batch panic");
    assert_eq!(report.quarantined_caches, 0, "solo retries succeeded — nothing poisoned");
    assert!(report.decode_steps > 0);
}

/// A deadline that expires between decode steps (each step stalls via
/// `decode:stall=40`) answers `DeadlineExceeded` carrying the tokens
/// generated so far; the abandoned cache is healthy and recyclable.
#[test]
fn deadline_expires_midflight_with_partial_tokens() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let plan = FaultPlan::parse("decode:stall=40").unwrap();
    let coord = Coordinator::new(cfg_with(ck.clone(), 4, Some(plan)));
    let client = coord.gen_client().unwrap();
    let h = std::thread::spawn(move || {
        let deadline = Some(Instant::now() + Duration::from_millis(150));
        client.generate_by(prompt_for(0, 2), 8, deadline)
    });
    let report = run_within(coord, 30);
    match h.join().unwrap() {
        Err(ServeError::DeadlineExceeded { partial }) => {
            assert!(
                !partial.is_empty() && partial.len() < 8,
                "mid-flight expiry returns the partial generation, got {} tokens",
                partial.len()
            );
        }
        other => panic!("expected a mid-flight DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(report.expired_midflight, 1);
    assert_eq!(report.quarantined_caches, 0, "an expired sequence's cache is healthy");
}

/// Dropping a `GenTicket` mid-generation must not wedge or poison the
/// loop: the orphaned response send fails silently and concurrent
/// traffic still completes bit-identically.
#[test]
fn dropped_ticket_mid_generation_does_not_hang_the_loop() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    let plan = FaultPlan::parse("decode:stall=10").unwrap();
    let coord = Coordinator::new(cfg_with(ck.clone(), 4, Some(plan)));
    let dropper = coord.gen_client().unwrap();
    let other = coord.gen_client().unwrap();
    let h1 = std::thread::spawn(move || {
        let ticket = dropper.submit(prompt_for(0, 3), 6).unwrap();
        drop(ticket); // client walks away mid-generation
    });
    let h2 = std::thread::spawn(move || {
        let p = prompt_for(1, 3);
        (p.clone(), other.generate(p, 4))
    });
    let report = run_within(coord, 30);
    h1.join().unwrap();
    let (prompt, res) = h2.join().unwrap();
    assert_eq!(res.unwrap().tokens, greedy_reference(&reference, &prompt, 4));
    assert_eq!(report.gen_requests, 2, "the orphaned generation still ran to completion");
    assert_eq!(report.quarantined_caches, 0);
}

/// Graceful drain with work in flight: shutdown stops admission and
/// answers the queue `ShuttingDown`, but the in-flight generation runs
/// to completion (slowed by a decode stall so the drain demonstrably
/// overlaps it).
#[test]
fn graceful_drain_finishes_inflight_and_rejects_queued() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    let plan = FaultPlan::parse("decode:stall=20").unwrap();
    // max_batch = 1: the second request must wait in the queue, where the
    // drain will find it
    let coord = Coordinator::new(cfg_with(ck.clone(), 1, Some(plan)));
    let stopper = coord.shutdown_handle();
    let first = coord.gen_client().unwrap();
    let second = coord.gen_client().unwrap();
    let h1 = std::thread::spawn(move || {
        let p = prompt_for(0, 4);
        (p.clone(), first.generate(p, 5))
    });
    let h2 = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(15));
        second.generate(prompt_for(1, 4), 5)
    });
    let stop = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(45));
        stopper.shutdown();
    });
    let report = run_within(coord, 30);
    stop.join().unwrap();
    let (prompt, res) = h1.join().unwrap();
    let got = res.expect("the in-flight generation must finish during the drain");
    assert_eq!(got.tokens.len(), 5);
    assert_eq!(got.tokens, greedy_reference(&reference, &prompt, 5));
    match h2.join().unwrap() {
        Err(ServeError::ShuttingDown) => {}
        other => panic!("queued work must be answered ShuttingDown, got {other:?}"),
    }
    assert!(report.drained, "the run ended via the shutdown signal");
    assert_eq!(report.rejected_shutdown, 1);
    assert_eq!(report.quarantined_caches, 0);
}

/// The chaos invariants with the **fast kernel tier and its persistent
/// worker pool active** (`kernel_tier: fast`, packed layout, 2 pool
/// workers): one seeded schedule panics inside prefill/decode layer walks
/// while pooled GEMV shards are in flight. Every submission still gets
/// exactly one typed response, the watchdog proves the loop (and the
/// pool) never hangs on an unwound panic, quarantine stays bounded by the
/// faults that actually unwound a walk, and survivors are bit-identical
/// to the fast packed plan's own greedy reference.
#[test]
fn chaos_with_fast_tier_pool_stays_typed_and_quarantined() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .group_size(16)
        .use_gptq(false)
        .packed(2)
        .kernels(KernelTier::Fast)
        .build()
        .unwrap();
    let calib: Vec<Vec<u16>> = (0..3).map(|i| prompt_for(i, 6)).collect();
    let stack = ServingStack::build(&ck, &calib, &recipe).unwrap();
    // survivors must match the fast packed plan (deterministic per tier),
    // not the oracle — the tier is part of the serving contract under test
    let reference = stack.compile();
    let mut cfg =
        recipe.coordinator_config(stack.checkpoint.clone(), Some(stack.sidecar.clone()));
    cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::ZERO };
    cfg.faults = Some(FaultPlan::parse("prefill:p=0.3,decode:p=0.2").unwrap().with_seed(515));
    let coord = Coordinator::new(cfg);

    let mut handles = Vec::new();
    for c in 0..3usize {
        let client = coord.gen_client().unwrap();
        handles.push(std::thread::spawn(move || {
            (0..3)
                .map(|i| {
                    let p = prompt_for(c, i);
                    (p.clone(), client.generate(p, 4))
                })
                .collect::<Vec<_>>()
        }));
    }
    let report = run_within(coord, 30);

    let mut responses = 0usize;
    let mut degraded = 0usize;
    for h in handles {
        for (prompt, res) in h.join().unwrap() {
            responses += 1;
            match res {
                Ok(Generated { tokens, .. }) => assert_eq!(
                    tokens,
                    greedy_reference(&reference, &prompt, 4),
                    "survivors must match the fast packed plan bit for bit"
                ),
                Err(ServeError::Overloaded)
                | Err(ServeError::Faulted(_))
                | Err(ServeError::ShuttingDown) => degraded += 1,
                Err(other) => panic!("untyped failure with the pool active: {other:?}"),
            }
        }
    }
    assert_eq!(responses, 9, "exactly one typed response per submission");
    assert_eq!(report.requests + report.shed_overloaded, 9, "the books must balance");
    assert!(
        report.quarantined_caches <= report.faulted,
        "quarantine only the caches a panic actually touched ({} quarantined, {} faulted)",
        report.quarantined_caches,
        report.faulted
    );
    assert!(degraded > 0, "the seeded schedule must trip at least one fault");
}

/// Pool-exhaustion chaos: the paged KV pool is squeezed to 4 pages while
/// three clients push 5-token prompts growing to 11 positions each (up to
/// 9 pages of concurrent demand) *and* seeded panics leak pages through
/// quarantine. Every submission still gets exactly one typed response,
/// the loop terminates (admission waits and preemption instead of
/// deadlocking), survivors are bit-identical to the dense reference, and
/// the pool's books balance: free + resident + leaked = total pages.
#[test]
fn pool_exhaustion_chaos_keeps_typed_responses_and_balanced_books() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    // one page = n_layers × (K,V) × P positions × d_model × 4 bytes
    let page_bytes = 2 * 2 * 4 * 24 * 4;
    for seed in [11u64, 22, 33] {
        let plan =
            FaultPlan::parse("prefill:p=0.2,decode:p=0.1").unwrap().with_seed(seed);
        let mut cfg = cfg_with(ck.clone(), 4, Some(plan));
        cfg.kv_page_positions = 4;
        cfg.kv_budget_bytes = 4 * page_bytes;
        let coord = Coordinator::new(cfg);

        let mut handles = Vec::new();
        for c in 0..3usize {
            let client = coord.gen_client().unwrap();
            handles.push(std::thread::spawn(move || {
                (0..3)
                    .map(|i| {
                        let p = prompt_for(c, i);
                        (p.clone(), client.generate(p, 6))
                    })
                    .collect::<Vec<_>>()
            }));
        }
        let report = run_within(coord, 30);

        let mut responses = 0usize;
        for h in handles {
            for (prompt, res) in h.join().unwrap() {
                responses += 1;
                match res {
                    Ok(Generated { tokens, .. }) => assert_eq!(
                        tokens,
                        greedy_reference(&reference, &prompt, 6),
                        "seed {seed}: survivors (preempted-and-requeued included) \
                         must match the reference bit for bit"
                    ),
                    Err(ServeError::Overloaded)
                    | Err(ServeError::Faulted(_))
                    | Err(ServeError::DeadlineExceeded { .. })
                    | Err(ServeError::ShuttingDown) => {}
                    Err(other) => panic!("seed {seed}: untyped failure {other:?}"),
                }
            }
        }
        assert_eq!(responses, 9, "seed {seed}: exactly one response per submission");
        assert_eq!(report.requests + report.shed_overloaded, 9, "seed {seed}: books");
        assert_eq!(report.kv_pages_total, 4, "seed {seed}: the budget bought 4 pages");
        assert_eq!(
            report.kv_pages_free + report.kv_pages_resident + report.kv_pages_leaked,
            report.kv_pages_total,
            "seed {seed}: pool accounting must balance"
        );
        assert_eq!(report.kv_pages_resident, 0, "seed {seed}: nothing in flight at exit");
        if report.quarantined_caches == 0 {
            assert_eq!(report.kv_pages_leaked, 0, "seed {seed}: leaks only via quarantine");
        }
        assert!(report.kv_pages_peak <= report.kv_pages_total, "seed {seed}");
        assert_eq!(report.kv_pool_bytes, 4 * page_bytes, "seed {seed}");
    }
}

/// A speculating recipe (packed oracle target, packed fast-tier draft)
/// under draft-site faults. The contract: a draft fault is never fatal
/// and never inexact — the sequence's draft cache is quarantined, the
/// sequence permanently downgrades to target-only decode, and every
/// response is still a typed `Ok` whose tokens are bit-identical to the
/// target plan decoding alone. The target's caches stay healthy.
#[test]
fn draft_faults_fall_back_to_target_only_greedy_identical() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let draft_recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .name("chaos-draft")
        .group_size(16)
        .use_gptq(false)
        .packed(1)
        .kernels(KernelTier::Fast)
        .build()
        .unwrap();
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .name("chaos-spec")
        .group_size(16)
        .use_gptq(false)
        .packed(1)
        .speculate(draft_recipe, 4)
        .build()
        .unwrap();
    let calib: Vec<Vec<u16>> = (0..3).map(|i| prompt_for(i, 7)).collect();
    let stack = ServingStack::build(&ck, &calib, &recipe).unwrap();
    // speculation must not change content, so survivors match the TARGET
    // plan's own greedy decode — draft faults only remove the speedup
    let reference = stack.compile();

    // -- every draft use faults: each sequence downgrades at mint --------
    let mut cfg =
        recipe.coordinator_config(stack.checkpoint.clone(), Some(stack.sidecar.clone()));
    cfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::ZERO };
    cfg.faults = Some(FaultPlan::parse("draft:always").unwrap());
    let coord = Coordinator::new(cfg);
    let mut handles = Vec::new();
    for c in 0..3usize {
        let client = coord.gen_client().unwrap();
        handles.push(std::thread::spawn(move || {
            (0..3)
                .map(|i| {
                    let p = prompt_for(c, i);
                    (p.clone(), client.generate(p, 4))
                })
                .collect::<Vec<_>>()
        }));
    }
    let report = run_within(coord, 30);
    for h in handles {
        for (prompt, res) in h.join().unwrap() {
            let got = res.expect("a draft fault must never fault the request outward");
            assert_eq!(
                got.tokens,
                greedy_reference(&reference, &prompt, 4),
                "fallback output must be the target plan's own greedy decode"
            );
        }
    }
    assert_eq!(report.requests, 9);
    assert_eq!(report.faulted, 0, "draft faults never surface as Faulted");
    assert_eq!(report.spec_fallbacks, 9, "every sequence fell back at draft mint");
    assert_eq!(
        report.quarantined_caches, 9,
        "exactly the 9 poisoned draft caches are quarantined — no target cache"
    );
    assert_eq!(report.spec_rounds, 0, "no speculative round survived draft:always");

    // -- deterministic one-shot fault (draft:nth=3): with a solo batch the
    // first two draft-site firings are request 1's mint and its first
    // proposal round, so the third lands only after a full round committed
    // — exactly one sequence downgrades, everything stays exact ----------
    let mut cfg =
        recipe.coordinator_config(stack.checkpoint.clone(), Some(stack.sidecar.clone()));
    cfg.policy = BatchPolicy { max_batch: 1, max_wait: Duration::ZERO };
    cfg.faults = Some(FaultPlan::parse("draft:nth=3").unwrap());
    let coord = Coordinator::new(cfg);
    let client = coord.gen_client().unwrap();
    let h = std::thread::spawn(move || {
        (0..3)
            .map(|i| {
                let p = prompt_for(1, i);
                (p.clone(), client.generate(p, 6))
            })
            .collect::<Vec<_>>()
    });
    let report = run_within(coord, 30);
    for (prompt, res) in h.join().unwrap() {
        let got = res.expect("a mid-stream draft fault must never fault the request outward");
        assert_eq!(
            got.tokens,
            greedy_reference(&reference, &prompt, 6),
            "mid-stream fallback must stay bit-identical to target-only decode"
        );
    }
    assert_eq!(report.faulted, 0);
    assert_eq!(report.spec_fallbacks, 1, "one sequence downgraded mid-stream");
    assert_eq!(report.quarantined_caches, 1, "only that sequence's draft cache");
    assert!(
        report.spec_rounds > 0,
        "rounds before the fault (and the unfaulted requests) still speculated"
    );
    assert!(report.spec_rolled_back > 0 || report.spec_accepted > 0);
}

/// Session chaos (ISSUE 10's satellite): a fault striking mid-turn
/// quarantines only that session's cache. The faulted turn answers one
/// typed `Faulted`, the session itself survives with its committed
/// transcript intact, its next turn transparently re-prefills from the
/// history (counted in `session_restores`), and a concurrent session the
/// fault did not touch stays bit-identical to the greedy reference. In
/// paged mode the poisoned cache leaks exactly its own pages and the
/// books still balance.
#[test]
fn session_fault_midturn_quarantines_only_that_cache() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    for page in [0usize, 4] {
        // solo batches + a single driving thread make the prefill-site
        // firing order exact: a#1, b#1, a#2 (faults), b#2, a#2 retry
        let plan = FaultPlan::parse("prefill:nth=3").unwrap();
        let mut cfg = cfg_with(ck.clone(), 1, Some(plan));
        cfg.kv_page_positions = page;
        let coord = Coordinator::new(cfg);
        let sc = coord.session_client().unwrap();
        let h = std::thread::spawn(move || {
            let d1a: Vec<u16> = prompt_for(0, 8)[..4].to_vec();
            let d1b: Vec<u16> = prompt_for(1, 8)[..4].to_vec();
            let d2: Vec<u16> = prompt_for(2, 8)[..3].to_vec();
            sc.open("a").unwrap();
            sc.open("b").unwrap();
            let a1 = sc.turn("a", d1a.clone(), 3).unwrap(); // firing 1
            let b1 = sc.turn("b", d1b.clone(), 3).unwrap(); // firing 2
            let mut hist_a = d1a;
            hist_a.extend_from_slice(&a1.tokens);
            let mut hist_b = d1b;
            hist_b.extend_from_slice(&b1.tokens);

            // firing 3: the injected panic unwinds a's delta prefill
            match sc.turn("a", d2.clone(), 3) {
                Err(ServeError::Faulted(msg)) => {
                    assert!(msg.contains("prefill"), "fault names its site, got {msg:?}")
                }
                other => panic!("the struck turn must answer Faulted, got {other:?}"),
            }
            // the session survived: transcript intact, nothing from the
            // faulted turn leaked into it
            assert_eq!(sc.tokens("a").unwrap(), hist_a, "fault must not pollute the history");

            let b2 = sc.turn("b", d2.clone(), 3).unwrap(); // firing 4
            let a2 = sc.turn("a", d2.clone(), 3).unwrap(); // firing 5: restore
            (hist_a, hist_b, d2, a2.tokens, b2.tokens)
        });
        let report = run_within(coord, 30);
        let (hist_a, hist_b, d2, a2, b2) = h.join().unwrap();

        let mut full_b = hist_b;
        full_b.extend_from_slice(&d2);
        assert_eq!(
            b2,
            greedy_reference(&reference, &full_b, 3),
            "page={page}: the untouched session must stay bit-identical"
        );
        let mut full_a = hist_a;
        full_a.extend_from_slice(&d2);
        assert_eq!(
            a2,
            greedy_reference(&reference, &full_a, 3),
            "page={page}: the restored turn must re-prefill to the exact same tokens"
        );

        assert_eq!(report.faulted, 1, "page={page}: exactly the struck turn faulted");
        assert_eq!(
            report.quarantined_caches, 1,
            "page={page}: only the struck session's cache is quarantined"
        );
        assert!(
            report.session_restores >= 1,
            "page={page}: the next touch of the quarantined session counts a restore"
        );
        assert_eq!(report.sessions_active, 2, "page={page}: both sessions survive the fault");
        assert_eq!(
            report.streamed_tokens, 12,
            "page={page}: four successful 3-token turns streamed; the faulted turn streamed none"
        );
        if page > 0 {
            assert_eq!(
                report.kv_pages_free + report.kv_pages_resident + report.kv_pages_leaked,
                report.kv_pages_total,
                "page={page}: books must balance around the quarantine"
            );
            assert!(
                report.kv_pages_leaked >= 1,
                "page={page}: the poisoned cache leaks its own pages"
            );
        }
    }
}

/// Bounded admission end to end: a depth-1 queue sheds every submission
/// past the first with a typed `Overloaded` before the loop even starts,
/// and the one admitted request still completes.
#[test]
fn overload_sheds_typed_overloaded() {
    quiet_injected_panics();
    let ck = tiny_ck();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    let mut cfg = cfg_with(ck.clone(), 4, None);
    cfg.queue_depth = 1;
    let coord = Coordinator::new(cfg);
    let client = coord.gen_client().unwrap();
    let prompt = prompt_for(2, 5);
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for _ in 0..4 {
        match client.submit(prompt.clone(), 3) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Overloaded) => shed += 1,
            Err(other) => panic!("expected Overloaded, got {other:?}"),
        }
    }
    drop(client);
    assert_eq!((tickets.len(), shed), (1, 3), "depth-1 queue admits exactly one");
    let report = run_within(coord, 30);
    let got = tickets.pop().unwrap().recv().unwrap().unwrap();
    assert_eq!(got.tokens, greedy_reference(&reference, &prompt, 3));
    assert_eq!(report.shed_overloaded, 3);
    assert_eq!(report.requests, 1);
}
