//! The packed weight layout's correctness contract:
//!
//! 1. A [`CompiledModel`] compiled with `WeightLayout::Packed` produces
//!    logits **bit-identical** to the dense fake-quant reference plan (and
//!    therefore to the reference `Engine`) over the same quantized
//!    checkpoint — across both architectures, FP4/INT4/8-bit weight
//!    formats, every scale constraint (none/M1/M2), odd hidden dims
//!    (trailing-nibble packing), RTN and GPTQ codes, and the KV-cached
//!    decode path.
//! 2. The packed plan's resident linear-weight bytes are ≤ 1/6 of the
//!    dense f32 plan for W4 — the memory claim `packed_bytes()` used to
//!    only account for.
//! 3. At kernel scale, the oracle GEMV stays bit-identical to the dense
//!    reference kernel on the shared **adversarial generator**'s cases
//!    (`tests/common`): zero/subnormal/non-finite group scales,
//!    all-negative rows, lane-unfriendly odd dims — the same inputs the
//!    fast tier is tolerance-gated on in `tests/kernel_tolerance.rs`.

mod common;

use common::{assert_bit_identical, calib, model_cfg};
use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::matmul::matmul_into;
use zeroquant_fp::tensor::packed_matmul::{packed_matmul_into, GemvScratch};
use zeroquant_fp::tensor::Matrix;

fn cfg(arch: Arch, name: &str, d: usize, heads: usize, ff: usize) -> ModelConfig {
    model_cfg(arch, &format!("packed-{name}"), d, heads, ff, 12)
}

/// Quantize `ck` under `scheme`/`constraint` (one packed recipe driven
/// through `ServingStack::build`), then check packed-vs-dense bit-identity
/// of full-window forwards (and the engine reference).
fn check(ck: &Checkpoint, scheme: &str, constraint: ScaleConstraint, use_gptq: bool, what: &str) {
    let recipe = QuantRecipe::builder(Scheme::parse(scheme).unwrap())
        .constraint(constraint)
        .group_size(16) // several groups per row even at toy dims
        .use_gptq(use_gptq)
        .packed(1)
        .build()
        .unwrap();
    let seqs = calib(3, 8, ck.config.vocab_size);
    let stack = ServingStack::build(ck, &seqs, &recipe).unwrap();
    assert!(!stack.sidecar.is_empty(), "{what}: sidecar missing");

    let qck = &stack.checkpoint;
    let opts = EngineOpts::with_act(recipe.scheme.activation);
    let dense = stack.compile_dense();
    let packed = stack.compile();

    let mut rng = Rng::seeded(0x7E57);
    let mut ds = dense.scratch();
    let mut ps = packed.scratch();
    let vocab = ck.config.vocab_size;
    for seq in [1usize, 5, ck.config.max_seq] {
        let tokens: Vec<u16> = (0..seq).map(|_| rng.below(vocab) as u16).collect();
        let want = dense.forward(&tokens, &mut ds).clone();
        let got = packed.forward(&tokens, &mut ps);
        assert_bit_identical(&want, got, &format!("{what} seq={seq}"));
        // and the reference engine agrees (the plan_equivalence contract
        // extended through the packed layout)
        let reference = Engine::with_opts(qck, opts).forward(&tokens);
        assert_bit_identical(&reference, got, &format!("{what} seq={seq} vs engine"));
    }
}

#[test]
fn packed_plan_bit_identical_across_formats_and_constraints() {
    for arch in [Arch::Opt, Arch::Llama] {
        let mut rng = Rng::seeded(0x5EED + arch as u64);
        let ck = Checkpoint::random(&cfg(arch, "even", 24, 3, 48), &mut rng);
        for scheme in ["w4a8-fp-fp", "w4a8-int-int", "w4a16-fpe3m0", "w8a8-fp-fp", "w8a8-int-int"] {
            for constraint in [
                ScaleConstraint::None,
                ScaleConstraint::M1,
                ScaleConstraint::M2 { rows: 4 },
            ] {
                let what = format!("{arch:?} {scheme} {}", constraint.label());
                check(&ck, scheme, constraint, false, &what);
            }
        }
    }
}

#[test]
fn packed_plan_bit_identical_with_gptq_codes() {
    for arch in [Arch::Opt, Arch::Llama] {
        let mut rng = Rng::seeded(0x69 + arch as u64);
        let ck = Checkpoint::random(&cfg(arch, "gptq", 24, 3, 48), &mut rng);
        let what = format!("{arch:?} gptq");
        check(&ck, "w4a8-fp-fp", ScaleConstraint::M2 { rows: 8 }, true, &what);
    }
}

#[test]
fn packed_plan_bit_identical_with_odd_dims() {
    // d_model = 25 and d_ff = 49: every linear has an odd input dim, so
    // each packed row ends on a trailing half-byte nibble.
    for arch in [Arch::Opt, Arch::Llama] {
        let mut rng = Rng::seeded(0x0DD + arch as u64);
        let ck = Checkpoint::random(&cfg(arch, "odd", 25, 5, 49), &mut rng);
        for scheme in ["w4a8-fp-fp", "w4a8-int-int"] {
            let what = format!("{arch:?} {scheme} odd-dims");
            check(&ck, scheme, ScaleConstraint::M1, false, &what);
        }
    }
}

#[test]
fn oracle_gemv_bit_identical_to_dense_on_adversarial_cases() {
    // The shared generator's cases (adversarial scales, all-negative rows,
    // lane-unfriendly shapes, LoRC fold) put the hardest inputs through
    // the oracle GEMV's bit-identity contract: fused decode-and-dot must
    // equal `matmul_into` over the decoded (and LoRC-folded) effective
    // matrix, bit for bit — non-finite groups must poison identically, not
    // merely approximately.
    for case in common::gemv_cases(0x6E40) {
        let w = &case.w;
        // dense reference: decode the effective matrix the contract names
        let eff = common::effective_dense(w, case.lorc.as_ref());
        let mut want = Matrix::zeros(case.x.rows, w.rows);
        matmul_into(&case.x, &eff.transpose(), &mut want);

        let e2_elems = case.lorc.as_ref().map_or(0, |l| l.e2_elems());
        for threads in [1usize, 3] {
            let mut got = Matrix::zeros(case.x.rows, w.rows);
            let mut s = GemvScratch::sized(w.cols, e2_elems);
            packed_matmul_into(&case.x, w, case.lorc.as_ref(), &mut got, &mut s, threads);
            assert_bit_identical(&want, &got, &format!("{} threads={threads}", case.name));
        }
    }
}

#[test]
fn packed_decode_path_matches_dense_decode() {
    // prefill + decode_step + decode_step_batch through the packed layout
    // match the dense plan token for token, bit for bit.
    let mut rng = Rng::seeded(0xDEC0);
    let ck = Checkpoint::random(&cfg(Arch::Llama, "decode", 24, 3, 48), &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M2 { rows: 8 })
        .use_gptq(false)
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(&ck, &calib(2, 8, 48), &recipe).unwrap();
    let dense = stack.compile_dense();
    let packed = stack.compile();

    let window: Vec<u16> = (0..10).map(|i| (i * 7 % 48) as u16).collect();
    let mut ds = dense.scratch();
    let mut ps = packed.scratch();
    let mut dc = dense.kv_cache();
    let mut pc = packed.kv_cache();
    let a = dense.prefill(&window[..6], &mut dc, &mut ds).clone();
    let b = packed.prefill(&window[..6], &mut pc, &mut ps);
    assert_bit_identical(&a, b, "prefill");
    for (t, &tok) in window[6..].iter().enumerate() {
        let a = dense.decode_step(tok, &mut dc, &mut ds).clone();
        let b = packed.decode_step(tok, &mut pc, &mut ps);
        assert_bit_identical(&a, b, &format!("decode step {t}"));
    }
    // continuous batching: two sequences interleaved
    let mut dcs = vec![dense.kv_cache(), dense.kv_cache()];
    let mut pcs = vec![packed.kv_cache(), packed.kv_cache()];
    for (c, p) in dcs.iter_mut().zip(pcs.iter_mut()) {
        dense.prefill(&window[..3], c, &mut ds);
        packed.prefill(&window[..3], p, &mut ps);
    }
    let a = dense.decode_step_batch(&[window[3], window[4]], &mut dcs, &mut ds).clone();
    let b = packed.decode_step_batch(&[window[3], window[4]], &mut pcs, &mut ps);
    assert_bit_identical(&a, b, "batched decode");
}

#[test]
fn sharded_packed_plan_matches_inline() {
    let mut rng = Rng::seeded(0x54A2);
    let ck = Checkpoint::random(&cfg(Arch::Opt, "shard", 24, 3, 48), &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .use_gptq(false)
        .packed(1)
        .build()
        .unwrap();
    let sharded_recipe =
        QuantRecipe::builder(recipe.scheme).use_gptq(false).packed(3).build().unwrap();
    let stack = ServingStack::build(&ck, &calib(2, 8, 48), &recipe).unwrap();
    let solo = stack.compile();
    let sharded = stack.with_recipe(&sharded_recipe).unwrap().compile();
    let tokens: Vec<u16> = (0..8).map(|i| (i * 5 % 48) as u16).collect();
    assert_bit_identical(
        &solo.forward_alloc(&tokens),
        &sharded.forward_alloc(&tokens),
        "threads=3",
    );
}

#[test]
fn packed_w4_weights_fit_in_a_sixth_of_dense() {
    // Big enough dims that per-group scale overhead is amortized the way
    // real models amortize it (group 64 ⇒ one f32 scale per 64 codes).
    let mut rng = Rng::seeded(0x512E);
    let ck = Checkpoint::random(&cfg(Arch::Opt, "mem", 64, 4, 128), &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .group_size(64)
        .use_gptq(false)
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(&ck, &calib(2, 8, 48), &recipe).unwrap();
    let dense = stack.compile_dense();
    let packed = stack.compile();
    let (db, pb) = (dense.linear_weight_bytes(), packed.linear_weight_bytes());
    assert!(pb > 0 && db > 0);
    assert!(
        pb * 6 <= db,
        "packed linear weights {pb} B must be ≤ 1/6 of dense {db} B"
    );
}
