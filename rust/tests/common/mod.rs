//! Shared fixtures for the integration suites: the toy model configs, the
//! seeded calibration streams, the bit-identity assertion, and the seeded
//! adversarial GEMV-input generator used by both `kernel_tolerance.rs`
//! (differential fast-vs-oracle gate) and `packed_equivalence.rs`
//! (bit-identity gate) — one generator, two contracts, so the fast tier is
//! tested on exactly the inputs the oracle's equivalence suite considers
//! hard.
#![allow(dead_code)]

use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::lorc::{LorcConfig, LorcFactors, PackedLorc};
use zeroquant_fp::model::{Arch, ModelConfig};
use zeroquant_fp::quant::{
    quantize_weight_rtn, PackedWeight, ScaleConstraint, WeightQuantConfig,
};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::Matrix;

/// Toy transformer config shared by the equivalence and tolerance suites.
/// `max_seq` is a parameter because the greedy-parity checks need room for
/// long generations while the equivalence grids stay tiny and fast.
pub fn model_cfg(
    arch: Arch,
    name: &str,
    d: usize,
    heads: usize,
    ff: usize,
    max_seq: usize,
) -> ModelConfig {
    ModelConfig {
        name: format!("{name}-{}", arch.name()),
        arch,
        vocab_size: 48,
        d_model: d,
        n_heads: heads,
        n_layers: 2,
        d_ff: ff,
        max_seq,
    }
}

/// Seeded calibration token streams (`n` sequences of `len` tokens).
pub fn calib(n: usize, len: usize, vocab: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::seeded(0xCA11);
    (0..n).map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect()).collect()
}

/// Element-wise `to_bits` equality — the bit-identity contract's assertion.
pub fn assert_bit_identical(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} a={x} b={y}");
    }
}

/// One generated fused-GEMV input: a batch of activations against a packed
/// weight (optionally LoRC-compensated), plus the label that names the
/// adversarial property being exercised.
pub struct GemvCase {
    pub name: String,
    pub x: Matrix,
    pub w: PackedWeight,
    pub lorc: Option<PackedLorc>,
}

/// The dense effective matrix a packed GEMV is specified against: the
/// decoded weights with the LoRC error rows folded in the fold's exact
/// accumulation order (`dequant_row + E₁E₂ row`, elementwise).
pub fn effective_dense(w: &PackedWeight, lorc: Option<&PackedLorc>) -> Matrix {
    let mut eff = w.dequantize();
    if let Some(l) = lorc {
        let mut e2 = vec![0.0; l.e2_elems()];
        l.decode_e2_into(&mut e2);
        let mut err = vec![0.0; w.cols];
        for j in 0..w.rows {
            l.err_row_into(j, &e2, &mut err);
            for (d, e) in eff.data[j * w.cols..(j + 1) * w.cols].iter_mut().zip(&err) {
                *d += e;
            }
        }
    }
    eff
}

fn quantize(wm: &Matrix, group: usize) -> PackedWeight {
    let cfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1)
        .with_group_size(group)
        .with_constraint(ScaleConstraint::None);
    PackedWeight::from_quantized(&quantize_weight_rtn(wm, &cfg))
}

fn lorc_for(wm: &Matrix, group: usize, rank: usize) -> PackedLorc {
    let cfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1)
        .with_group_size(group)
        .with_constraint(ScaleConstraint::None);
    let q = quantize_weight_rtn(wm, &cfg);
    let f = LorcFactors::compute(
        wm,
        &q.dequantize(),
        &LorcConfig { rank, factor_format: NumericFormat::FP8_E4M3 },
    )
    .expect("lorc factors on a toy matrix");
    PackedLorc::pack(&[(wm.rows, Some(&f))])
}

/// Seeded generator of adversarial fused-GEMV inputs. Properties covered:
///
/// * shape grid including `cols % group != 0`, `cols % 8 != 0` (the fast
///   tier's lane width) and single-row batches;
/// * all-negative weight rows (sign-carrying codes end to end);
/// * adversarial **group scales**, mutated post-pack on the multiply
///   dequant plan: exact zeros (dead groups), subnormals (underflow on
///   dequant), and non-finite scales (`inf`/`NaN` groups must poison both
///   tiers identically rather than diverge);
/// * a LoRC-compensated case (error-row fold on top of decode).
///
/// Both suites iterate this one list: `packed_equivalence.rs` asserts the
/// oracle GEMV stays bit-identical to the dense reference on every case,
/// `kernel_tolerance.rs` asserts the fast tier stays inside the ULP gate
/// on the same cases.
pub fn gemv_cases(seed: u64) -> Vec<GemvCase> {
    let mut rng = Rng::seeded(seed);
    let mut cases = Vec::new();
    let mut push = |name: &str, x: Matrix, w: PackedWeight, lorc: Option<PackedLorc>| {
        cases.push(GemvCase { name: name.to_string(), x, w, lorc });
    };

    // shape grid: (batch rows, weight out-rows, in-cols, group)
    for &(b, rows, cols, group) in &[
        (1usize, 8usize, 32usize, 8usize),
        (3, 7, 29, 8),   // cols % 8 != 0 and cols % group != 0
        (5, 16, 33, 16), // odd cols against a wider group
        (2, 5, 8, 4),    // tiny: fewer rows than a typical worker count
        (4, 24, 64, 32),
    ] {
        let wm = Matrix::randn(rows, cols, 0.05, &mut rng);
        let x = Matrix::randn(b, cols, 0.5, &mut rng);
        push(&format!("randn b{b} {rows}x{cols} g{group}"), x, quantize(&wm, group), None);
    }

    // all-negative weight rows
    {
        let mut wm = Matrix::randn(9, 24, 0.05, &mut rng);
        for v in wm.data.iter_mut() {
            *v = -v.abs() - 1e-3;
        }
        let x = Matrix::randn(3, 24, 0.5, &mut rng);
        push("all-negative rows", x, quantize(&wm, 8), None);
    }

    // adversarial scales, mutated post-pack (unconstrained scales select
    // the multiply dequant plan, which reads `scales` at decode time)
    {
        let wm = Matrix::randn(10, 32, 0.05, &mut rng);
        let x = Matrix::randn(3, 32, 0.5, &mut rng);

        let mut w = quantize(&wm, 8);
        assert!(
            !w.uses_shift_dequant(),
            "unconstrained scales must select the multiply plan"
        );
        for (g, s) in w.scales.iter_mut().enumerate() {
            if g % 3 == 0 {
                *s = 0.0; // dead group
            } else if g % 3 == 1 {
                *s = f32::MIN_POSITIVE / 4.0; // subnormal scale
            }
        }
        push("zero + subnormal scales", x.clone(), w, None);

        let mut w = quantize(&wm, 8);
        for (g, s) in w.scales.iter_mut().enumerate() {
            if g % 4 == 0 {
                *s = f32::INFINITY;
            } else if g % 4 == 1 {
                *s = f32::NAN;
            }
        }
        push("non-finite scales", x, w, None);
    }

    // LoRC fold riding on the decode
    {
        let wm = Matrix::randn(12, 32, 0.05, &mut rng);
        let x = Matrix::randn(3, 32, 0.5, &mut rng);
        let l = lorc_for(&wm, 8, 4);
        push("lorc fold", x, quantize(&wm, 8), Some(l));
    }

    cases
}
