//! The compiled execution plan's correctness contract:
//!
//! 1. `CompiledModel` logits are **bit-identical** to `Engine::forward`
//!    across both architectures, every `NumericFormat` activation setting,
//!    and every sequence length `1..=max_seq`.
//! 2. The FP8/FP4 LUT quantizer matches the `FpFormat::quantize` oracle on
//!    every f32 exponent bucket and on all 2^16 upper-half bit patterns
//!    (plus every representable code of every format).

use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::{Engine, EngineOpts, Site};
use zeroquant_fp::formats::{FpFormat, NumericFormat};
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::{CompiledModel, FpQuantLut};
use zeroquant_fp::quant::Scheme;
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

/// Compile the plan the way the serving stack does: a W16 recipe (weights
/// untouched) with `fmt` activations through [`ServingStack::build`] — so
/// the whole equivalence grid also covers the recipe → plan wiring.
fn stack_model(ck: &Checkpoint, fmt: NumericFormat) -> CompiledModel {
    let recipe = QuantRecipe::builder(Scheme { weight: NumericFormat::F16, activation: fmt })
        .build()
        .unwrap();
    ServingStack::build(ck, &[], &recipe).unwrap().compile()
}

fn tiny(arch: Arch) -> ModelConfig {
    ModelConfig {
        name: format!("equiv-{}", arch.name()),
        arch,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 12,
    }
}

const ACT_FORMATS: [NumericFormat; 8] = [
    NumericFormat::F16,
    NumericFormat::FP8_E4M3,
    NumericFormat::FP8_E5M2,
    NumericFormat::FP4_E2M1,
    NumericFormat::FP4_E3M0,
    NumericFormat::INT8,
    NumericFormat::INT8_ASYM,
    NumericFormat::INT4,
];

fn assert_bit_identical(reference: &zeroquant_fp::tensor::Matrix, compiled: &zeroquant_fp::tensor::Matrix, what: &str) {
    assert_eq!((reference.rows, reference.cols), (compiled.rows, compiled.cols), "{what}: shape");
    for (i, (a, b)) in reference.data.iter().zip(&compiled.data).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: element {i} reference={a} compiled={b}"
        );
    }
}

#[test]
fn compiled_logits_bit_identical_across_arch_format_seqlen() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0x5EED + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        for fmt in ACT_FORMATS {
            let opts = EngineOpts::with_act(fmt);
            let engine = Engine::with_opts(&ck, opts);
            let model = stack_model(&ck, fmt);
            let mut scratch = model.scratch();
            for seq in 1..=cfg.max_seq {
                let tokens: Vec<u16> =
                    (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
                let reference = engine.forward(&tokens);
                let compiled = model.forward(&tokens, &mut scratch);
                assert_bit_identical(
                    &reference,
                    compiled,
                    &format!("{arch:?} act={} seq={seq}", fmt.name()),
                );
            }
        }
    }
}

#[test]
fn compiled_logits_bit_identical_with_injected_outliers() {
    // The regime the paper cares about: strong activation outliers.
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xB0B + arch as u64);
        let mut ck = Checkpoint::random(&cfg, &mut rng);
        zeroquant_fp::model::inject_outliers(
            &mut ck,
            zeroquant_fp::model::OutlierSpec { alpha: 64.0, channels: 3 },
            &mut rng,
        );
        for fmt in [NumericFormat::FP8_E4M3, NumericFormat::INT8] {
            let opts = EngineOpts::with_act(fmt);
            let tokens: Vec<u16> =
                (0..cfg.max_seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
            let reference = Engine::with_opts(&ck, opts).forward(&tokens);
            let compiled = stack_model(&ck, fmt).forward_alloc(&tokens);
            assert_bit_identical(&reference, &compiled, &format!("{arch:?} act={}", fmt.name()));
        }
    }
}

#[test]
fn compiled_observed_activations_bit_identical() {
    // Calibration parity: the Hessians GPTQ sees must not depend on which
    // engine ran the forward pass.
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xCA11B + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let tokens: Vec<u16> =
            (0..cfg.max_seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();

        let mut ref_sites: std::collections::HashMap<Site, zeroquant_fp::tensor::Matrix> =
            std::collections::HashMap::new();
        Engine::new(&ck).forward_observed(&tokens, &mut |site, x| {
            ref_sites.insert(site, x.clone());
        });

        let model = stack_model(&ck, NumericFormat::F16);
        let mut scratch = model.scratch();
        let mut n = 0usize;
        model.forward_observed(&tokens, &mut scratch, &mut |site, x| {
            let reference = ref_sites.get(&site).expect("site seen by reference");
            for (a, b) in reference.data.iter().zip(&x.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{arch:?} site {site:?}");
            }
            n += 1;
        });
        assert_eq!(n, ref_sites.len());
    }
}

#[test]
fn lut_matches_oracle_on_every_exponent_bucket() {
    // Every f32 exponent byte × a spread of mantissa patterns × both signs.
    let mantissas: [u32; 9] = [
        0x000000, 0x000001, 0x200000, 0x3fffff, 0x400000, 0x400001, 0x600000, 0x7ffffe,
        0x7fffff,
    ];
    for fmt in [FpFormat::E4M3, FpFormat::E5M2, FpFormat::E2M1, FpFormat::E3M0] {
        let lut = FpQuantLut::new(fmt);
        for e8 in 0u32..=255 {
            for &m in &mantissas {
                for sign in [0u32, 1] {
                    let bits = (sign << 31) | (e8 << 23) | m;
                    let x = f32::from_bits(bits);
                    let a = lut.quantize(x);
                    let b = fmt.quantize(x);
                    if b.is_nan() {
                        assert!(a.is_nan(), "{}: bits={bits:#010x}", fmt.name());
                    } else {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{}: x={x:e} (bits {bits:#010x}) lut={a} oracle={b}",
                            fmt.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lut_matches_oracle_on_all_u16_upper_patterns() {
    // All 2^16 values of the f32 upper half-word (sign+exp+7 mantissa bits):
    // a bf16-dense sweep of the entire f32 range, both tails included.
    for fmt in [FpFormat::E4M3, FpFormat::E5M2, FpFormat::E2M1, FpFormat::E3M0] {
        let lut = FpQuantLut::new(fmt);
        for code in 0u32..=0xffff {
            let x = f32::from_bits(code << 16);
            let a = lut.quantize(x);
            let b = fmt.quantize(x);
            if b.is_nan() {
                assert!(a.is_nan(), "{}: code={code:#06x}", fmt.name());
            } else {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: code={code:#06x} x={x:e} lut={a} oracle={b}",
                    fmt.name()
                );
            }
        }
    }
}

#[test]
fn lut_fixes_every_representable_code() {
    // decode(code) must be a fixed point of the LUT quantizer for all codes
    // of all formats (the idempotence property the oracle guarantees).
    for fmt in [FpFormat::E4M3, FpFormat::E5M2, FpFormat::E2M1, FpFormat::E3M0] {
        let lut = FpQuantLut::new(fmt);
        for code in 0..fmt.code_count() as u16 {
            let v = fmt.decode(code);
            if !v.is_finite() || (v as f64) > fmt.max_finite() {
                continue;
            }
            assert_eq!(
                lut.quantize(v).to_bits(),
                fmt.quantize(v).to_bits(),
                "{} code {code}",
                fmt.name()
            );
        }
    }
}

#[test]
fn tokenwise_lut_path_matches_reference_quantizer() {
    // The full A8 hot path (absmax scale + divide + quantize + rescale) on
    // realistic activation rows, against quant::fake_quant_tokenwise.
    let mut rng = Rng::seeded(0xF00D);
    for fmt in [
        NumericFormat::FP8_E4M3,
        NumericFormat::FP8_E5M2,
        NumericFormat::FP4_E2M1,
        NumericFormat::FP4_E3M0,
    ] {
        let NumericFormat::Fp(fp) = fmt else { unreachable!() };
        let lut = FpQuantLut::new(fp);
        for _ in 0..50 {
            let mut a: Vec<f32> = (0..96).map(|_| rng.normal_f32() * 2.0).collect();
            a[17] = 40.0 * rng.normal_f32(); // outlier channel
            let mut m_ref = zeroquant_fp::tensor::Matrix::from_vec(1, 96, a.clone());
            zeroquant_fp::quant::fake_quant_tokenwise(
                &mut m_ref,
                &zeroquant_fp::quant::ActQuantConfig::new(fmt),
            );
            let mut b = a;
            lut.fake_quant_row(&mut b);
            for (x, y) in m_ref.data.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", fmt.name());
            }
        }
    }
}
