//! Cross-module integration tests: the PTQ pipeline end-to-end on trained-
//! shaped models, the paper's qualitative claims at unit scale, and the
//! CLI surface. No external files needed (checkpoints are synthesized).

use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::eval::perplexity;
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{inject_outliers, Arch, Checkpoint, ModelConfig, OutlierSpec};
use zeroquant_fp::pipeline::ptq;
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

fn test_config(arch: Arch) -> ModelConfig {
    ModelConfig {
        name: "itest".into(),
        arch,
        vocab_size: 64,
        d_model: 32,
        n_heads: 4,
        n_layers: 2,
        d_ff: 64,
        max_seq: 32,
    }
}

/// A "pseudo-trained" checkpoint: random init plus a deterministic
/// low-rank structure so weights have correlated rows/columns like trained
/// models (GPTQ and LoRC behave qualitatively differently on pure noise).
fn pseudo_trained(arch: Arch, seed: u64) -> Checkpoint {
    let cfg = test_config(arch);
    let mut rng = Rng::seeded(seed);
    let mut ck = Checkpoint::random(&cfg, &mut rng);
    for layer in 0..cfg.n_layers {
        for (tensor, _) in zeroquant_fp::pipeline::quantizable_tensors(arch, layer) {
            let w = ck.get(&tensor).clone();
            let r = 4.min(w.rows).min(w.cols);
            let u = zeroquant_fp::tensor::Matrix::randn(w.rows, r, 0.08, &mut rng);
            let v = zeroquant_fp::tensor::Matrix::randn(r, w.cols, 0.08, &mut rng);
            let mut lowrank = u.matmul(&v);
            lowrank.add_assign(&w);
            *ck.get_mut(&tensor) = lowrank;
        }
    }
    ck
}

fn calib(ck: &Checkpoint, n: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::seeded(777);
    (0..n)
        .map(|_| {
            (0..ck.config.max_seq)
                .map(|_| rng.below(ck.config.vocab_size) as u16)
                .collect()
        })
        .collect()
}

fn eval_tokens(ck: &Checkpoint, n: usize) -> Vec<u16> {
    let mut rng = Rng::seeded(888);
    (0..n).map(|_| rng.below(ck.config.vocab_size) as u16).collect()
}

#[test]
fn full_ptq_pipeline_all_schemes() {
    for arch in [Arch::Opt, Arch::Llama] {
        let ck = pseudo_trained(arch, 42);
        let seqs = calib(&ck, 4);
        let toks = eval_tokens(&ck, 320);
        let base = perplexity(&ck, EngineOpts::default(), &toks, 32).ppl();
        for scheme in ["w8a8-fp-fp", "w4a8-fp-fp", "w4a8-int-int", "w8a8-int-fp"] {
            let cfg = QuantRecipe::builder(Scheme::parse(scheme).unwrap()).build().unwrap();
            let out = ptq(&ck, &seqs, None, &cfg);
            let (qck, report) = (out.checkpoint, out.report);
            let ppl = perplexity(&qck, cfg.engine_opts(), &toks, 32).ppl();
            assert!(
                ppl.is_finite() && ppl < base * 4.0,
                "{arch:?}/{scheme}: base={base} quant={ppl}"
            );
            assert!(report.compression() > 1.5, "{scheme}");
        }
    }
}

#[test]
fn w8a8_fp_is_near_lossless_on_engine_ppl() {
    let ck = pseudo_trained(Arch::Opt, 43);
    let seqs = calib(&ck, 4);
    let toks = eval_tokens(&ck, 640);
    let base = perplexity(&ck, EngineOpts::default(), &toks, 32).ppl();
    let cfg = QuantRecipe::builder(Scheme::parse("w8a8-fp-fp").unwrap()).build().unwrap();
    let qck = ptq(&ck, &seqs, None, &cfg).checkpoint;
    let q = perplexity(&qck, cfg.engine_opts(), &toks, 32).ppl();
    let rel = (q - base).abs() / base;
    assert!(rel < 0.02, "base={base} q={q} rel={rel}");
}

#[test]
fn outlier_injection_reproduces_table1_ordering() {
    // the paper's central claim at integration scale: with outliers,
    // A8-INT degrades much more than A8-FP.
    let mut ck = pseudo_trained(Arch::Opt, 44);
    let mut rng = Rng::seeded(9);
    inject_outliers(&mut ck, OutlierSpec::new(64.0), &mut rng);
    let toks = eval_tokens(&ck, 640);
    let p16 = perplexity(&ck, EngineOpts::default(), &toks, 32).ppl();
    let p_int = perplexity(&ck, EngineOpts::with_act(NumericFormat::INT8), &toks, 32).ppl();
    let p_fp = perplexity(&ck, EngineOpts::with_act(NumericFormat::FP8_E4M3), &toks, 32).ppl();
    let d_int = p_int - p16;
    let d_fp = p_fp - p16;
    assert!(
        d_fp.abs() < d_int.abs() / 2.0,
        "p16={p16} int={p_int} fp={p_fp}"
    );
}

#[test]
fn lorc_and_constraints_compose() {
    let ck = pseudo_trained(Arch::Opt, 45);
    let seqs = calib(&ck, 4);
    let scheme = Scheme::parse("w4a8-fp-fp").unwrap();
    for constraint in [
        ScaleConstraint::None,
        ScaleConstraint::M1,
        ScaleConstraint::M2 { rows: 8 },
    ] {
        let cfg = QuantRecipe::builder(scheme)
            .constraint(constraint)
            .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
            .build()
            .unwrap();
        let out = ptq(&ck, &seqs, None, &cfg);
        assert!(out.report.total_weight_mse().is_finite());
        // every effective weight is finite
        for (name, m) in &out.checkpoint.tensors {
            assert!(m.data.iter().all(|x| x.is_finite()), "{name}");
        }
    }
}

#[test]
fn lorc_recovers_constraint_damage() {
    // Table 3's second-order claim: LoRC mitigates the M1 degradation in
    // weight space.
    let ck = pseudo_trained(Arch::Opt, 46);
    let seqs = calib(&ck, 4);
    let scheme = Scheme::parse("w4a8-fp-fp").unwrap();
    let cfg_m1 = QuantRecipe::builder(scheme)
        .constraint(ScaleConstraint::M1)
        .build()
        .unwrap();
    let cfg_m1_lorc = QuantRecipe::builder(scheme)
        .constraint(ScaleConstraint::M1)
        .lorc(LorcConfig { rank: 8, factor_format: NumericFormat::F16 })
        .build()
        .unwrap();
    let r0 = ptq(&ck, &seqs, None, &cfg_m1).report;
    let r1 = ptq(&ck, &seqs, None, &cfg_m1_lorc).report;
    assert!(r1.total_weight_mse() < r0.total_weight_mse() * 0.8);
}

#[test]
fn cast_to_e5m2_is_cheap_in_quality() {
    let ck = pseudo_trained(Arch::Opt, 47);
    let seqs = calib(&ck, 4);
    let toks = eval_tokens(&ck, 320);
    let scheme = Scheme::parse("w4a8-fp-fp").unwrap();
    let plain = QuantRecipe::builder(scheme).build().unwrap();
    let cast = QuantRecipe::builder(scheme).cast_fp4_to_e5m2(true).build().unwrap();
    let q0 = ptq(&ck, &seqs, None, &plain).checkpoint;
    let q1 = ptq(&ck, &seqs, None, &cast).checkpoint;
    let p0 = perplexity(&q0, plain.engine_opts(), &toks, 32).ppl();
    let p1 = perplexity(&q1, cast.engine_opts(), &toks, 32).ppl();
    // FP4*pow2-scale values are exactly representable in E5M2 when scales
    // are powers of two; with free scales the cast costs at most a little.
    assert!((p1 - p0).abs() / p0 < 0.05, "p0={p0} p1={p1}");
}

#[test]
fn rtn_vs_gptq_on_structured_weights() {
    // On correlated (pseudo-trained) weights GPTQ should beat RTN in
    // output MSE summed over the model's linears.
    let ck = pseudo_trained(Arch::Opt, 48);
    let seqs = calib(&ck, 6);
    let toks = eval_tokens(&ck, 640);
    let scheme = Scheme::parse("w4a8-int-int").unwrap();
    let gptq_cfg = QuantRecipe::builder(scheme).build().unwrap();
    let rtn_cfg = QuantRecipe::builder(scheme).use_gptq(false).build().unwrap();
    let qg = ptq(&ck, &seqs, None, &gptq_cfg).checkpoint;
    let qr = ptq(&ck, &seqs, None, &rtn_cfg).checkpoint;
    // compare logits fidelity vs the fp model
    let window: Vec<u16> = toks[..32].to_vec();
    let base = Engine::new(&ck).forward(&window);
    let eg = Engine::new(&qg).forward(&window).sub(&base).fro_norm();
    let er = Engine::new(&qr).forward(&window).sub(&base).fro_norm();
    assert!(eg < er * 1.25, "gptq={eg} rtn={er}"); // gptq no worse (usually better)
}

#[test]
fn cli_parses_and_reports_errors() {
    let run = |args: &[&str]| {
        zeroquant_fp::cli::run(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    };
    assert!(run(&["bogus-cmd"]).is_err());
    assert!(run(&["table"]).is_err()); // missing --id
    assert!(run(&["quantize"]).is_err()); // missing --ckpt
    assert!(run(&["eval", "--ckpt", "/nonexistent.zqckpt"]).is_err());
    assert!(run(&[]).is_ok()); // usage
}

#[test]
fn checkpoint_quantize_roundtrip_via_files() {
    let dir = std::env::temp_dir().join("zqfp_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let ck = pseudo_trained(Arch::Llama, 49);
    let src = dir.join("model.zqckpt");
    ck.save(&src).unwrap();
    // write a calib file
    let calib_path = dir.join("calib.tok");
    let calib_toks: Vec<u16> = eval_tokens(&ck, 32 * 4);
    zeroquant_fp::data::write_tokens(&calib_path, &calib_toks).unwrap();
    let out = dir.join("quant.zqckpt");
    let args: Vec<String> = [
        "quantize",
        "--ckpt",
        src.to_str().unwrap(),
        "--scheme",
        "w4a8-fp-fp",
        "--lorc",
        "--out",
        out.to_str().unwrap(),
        "--data",
        dir.to_str().unwrap(),
        "--seq",
        "32",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    zeroquant_fp::cli::run(&args).unwrap();
    let qck = Checkpoint::load(&out).unwrap();
    assert_eq!(qck.tensors.len(), ck.tensors.len());
    // quantized weights differ from originals
    assert_ne!(
        qck.get("layers.0.attn.q.w").data,
        ck.get("layers.0.attn.q.w").data
    );
}
