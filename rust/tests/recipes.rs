//! The recipe API's contracts, end to end:
//!
//! 1. Every in-tree preset constructs, validates, and round-trips through
//!    JSON bit-exactly (the CI `recipes` job runs this file so presets
//!    cannot silently rot).
//! 2. JSON round-trip across the full knob grid:
//!    `recipe == from_json(to_json(recipe))` for every valid combination
//!    of scheme × constraint × GPTQ × cast × LoRC × layout × KV format ×
//!    batching limits.
//! 3. Every invalid combination [`RecipeError`] can report is actually
//!    rejected, with its typed variant.
//! 4. `--recipe <file>` + explicit flags: override precedence.
//! 5. Presets drive [`ServingStack::build`] to plans that are
//!    bit-identical to the reference engine (and, for quantized presets,
//!    packed ≡ dense) — the recipe → PTQ → sidecar → plan wiring serves
//!    the same bits the equivalence suites pin down.

use zeroquant_fp::cli::Args;
use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::{Engine, KernelTier, WeightLayout};
use zeroquant_fp::formats::{FpFormat, NumericFormat};
use zeroquant_fp::gptq::GptqConfig;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::{PRESET_NAMES, QuantRecipe, RecipeBuilder, RecipeError, SpeculateConfig};
use zeroquant_fp::rng::Rng;

fn tiny_ck(arch: Arch) -> Checkpoint {
    let cfg = ModelConfig {
        name: "recipe-test".into(),
        arch,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 12,
    };
    let mut rng = Rng::seeded(0x8EC1);
    Checkpoint::random(&cfg, &mut rng)
}

fn calib(n: usize, len: usize) -> Vec<Vec<u16>> {
    let mut rng = Rng::seeded(0x8EC2);
    (0..n).map(|_| (0..len).map(|_| rng.below(48) as u16).collect()).collect()
}

fn assert_bit_identical(
    a: &zeroquant_fp::tensor::Matrix,
    b: &zeroquant_fp::tensor::Matrix,
    what: &str,
) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} a={x} b={y}");
    }
}

#[test]
fn every_preset_validates_and_round_trips() {
    for name in PRESET_NAMES {
        let r = QuantRecipe::preset(name).unwrap();
        assert_eq!(r.name, name);
        r.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!r.summary().is_empty());
        // compact and pretty JSON both reproduce the recipe exactly
        let compact = QuantRecipe::from_json(&r.to_json())
            .unwrap_or_else(|e| panic!("{name} compact: {e}"));
        assert_eq!(compact, r, "{name}: compact round-trip");
        let pretty = QuantRecipe::from_json(&r.to_json_pretty())
            .unwrap_or_else(|e| panic!("{name} pretty: {e}"));
        assert_eq!(pretty, r, "{name}: pretty round-trip");
        // the --recipe resolver finds every preset by name
        assert_eq!(QuantRecipe::load(name).unwrap(), r);
    }
}

#[test]
fn json_round_trip_across_the_knob_grid() {
    let schemes = [
        "w16a16",
        "w16a8-int",
        "w8a8-int-int",
        "w8a8-fp-fp",
        "w4a8-fp-fp",
        "w4a8-int-int",
        "w4a8-int-fp",
        "w4a8-fpe3m0-fp",
        "w4a16-fp",
    ];
    let constraints = [
        ScaleConstraint::None,
        ScaleConstraint::M1,
        ScaleConstraint::M2 { rows: 4 },
        ScaleConstraint::M2 { rows: 32 },
    ];
    let lorcs = [
        None,
        Some(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 }),
        Some(LorcConfig { rank: 8, factor_format: NumericFormat::F16 }),
    ];
    let kvs = [None, Some(FpFormat::E4M3), Some(FpFormat::E5M2)];
    let mut valid = 0usize;
    let mut rejected = 0usize;
    for scheme_s in schemes {
        let scheme = Scheme::parse(scheme_s).unwrap();
        let w16 = matches!(scheme.weight, NumericFormat::F16);
        for constraint in constraints {
            for lorc in lorcs {
                for packed_threads in [0usize, 1, 3] {
                    for kv in kvs {
                        for use_gptq in [true, false] {
                            let mut b = RecipeBuilder::new(scheme)
                                .constraint(constraint)
                                .use_gptq(use_gptq)
                                .cast_fp4_to_e5m2(scheme_s.contains("w4"))
                                .kv_quant(kv)
                                .group_size(16)
                                .max_batch(4)
                                .max_wait_ms(0);
                            if let Some(l) = lorc {
                                b = b.lorc(l);
                            }
                            if packed_threads > 0 {
                                b = b.packed(packed_threads);
                            }
                            match b.build() {
                                Ok(r) => {
                                    valid += 1;
                                    let back = QuantRecipe::from_json(&r.to_json())
                                        .unwrap_or_else(|e| {
                                            panic!("{scheme_s} {}: {e}", constraint.label())
                                        });
                                    assert_eq!(back, r, "{scheme_s} {}", constraint.label());
                                }
                                Err(e) => {
                                    // the only invalid cells in this grid are
                                    // the W16 ones (nothing to pack/compensate)
                                    rejected += 1;
                                    assert!(w16, "{scheme_s}: unexpected rejection {e}");
                                    assert!(matches!(
                                        e,
                                        RecipeError::PackedNeedsCodes
                                            | RecipeError::LorcNeedsQuantizedWeights
                                    ));
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(valid > 1000, "grid too small: {valid}");
    assert!(rejected > 0, "the grid must exercise rejections too");
}

#[test]
fn every_recipe_error_variant_rejects() {
    let w4 = Scheme::parse("w4a8-fp-fp").unwrap();
    let w16 = Scheme::parse("w16a16").unwrap();
    // builder-level rejections
    assert_eq!(
        RecipeBuilder::new(w4).group_size(0).build().unwrap_err(),
        RecipeError::GroupSizeZero
    );
    assert_eq!(
        RecipeBuilder::new(w4)
            .constraint(ScaleConstraint::M2 { rows: 0 })
            .build()
            .unwrap_err(),
        RecipeError::M2ZeroRows
    );
    assert_eq!(
        RecipeBuilder::new(w16).packed(1).build().unwrap_err(),
        RecipeError::PackedNeedsCodes
    );
    assert_eq!(
        RecipeBuilder::new(w16).lorc(LorcConfig::default()).build().unwrap_err(),
        RecipeError::LorcNeedsQuantizedWeights
    );
    assert_eq!(
        RecipeBuilder::new(w4)
            .lorc(LorcConfig { rank: 0, factor_format: NumericFormat::FP8_E4M3 })
            .build()
            .unwrap_err(),
        RecipeError::LorcRankZero
    );
    assert_eq!(
        RecipeBuilder::new(w4)
            .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::INT4 })
            .build()
            .unwrap_err(),
        RecipeError::LorcFactorFormatNotFp(NumericFormat::INT4)
    );
    assert_eq!(
        RecipeBuilder::new(w4).max_batch(0).build().unwrap_err(),
        RecipeError::MaxBatchZero
    );
    // GPTQ hyper-parameters are validated too: negative damping would
    // loop the Cholesky-escalation forever, NaN would poison it, and a
    // zero column block would panic the sweep
    assert_eq!(
        RecipeBuilder::new(w4)
            .gptq(GptqConfig { percdamp: -1.0, block_size: 128 })
            .build()
            .unwrap_err(),
        RecipeError::GptqPercdampInvalid
    );
    assert_eq!(
        RecipeBuilder::new(w4)
            .gptq(GptqConfig { percdamp: f64::NAN, block_size: 128 })
            .build()
            .unwrap_err(),
        RecipeError::GptqPercdampInvalid
    );
    assert_eq!(
        RecipeBuilder::new(w4)
            .gptq(GptqConfig { percdamp: 0.01, block_size: 0 })
            .build()
            .unwrap_err(),
        RecipeError::GptqBlockSizeZero
    );
    // name-resolution rejection
    assert_eq!(
        QuantRecipe::preset("w2a2").unwrap_err(),
        RecipeError::UnknownPreset("w2a2".to_string())
    );
    // JSON-level rejections
    assert_eq!(
        QuantRecipe::from_json(r#"{"kv_cache": "int8"}"#).unwrap_err(),
        RecipeError::KvCacheNotFp(NumericFormat::INT8)
    );
    // ...but the CLI's "none"/"off" spelling means exactly null in a file
    // (NumericFormat::parse would read "none" as F16 and mis-reject it)
    let off = QuantRecipe::from_json(r#"{"kv_cache": "none"}"#).unwrap();
    assert_eq!(off.kv_quant, None);
    for bad in [
        "{",                          // malformed document
        "[1, 2]",                     // wrong top-level type
        r#"{"weigth": "e2m1"}"#,      // typo'd key must not be ignored
        r#"{"group_size": "many"}"#,  // wrong field type
        r#"{"weight": "float7"}"#,    // unknown format
        r#"{"constraint": "m3"}"#,    // unknown constraint
        r#"{"layout": "sparse"}"#,    // unknown layout
        r#"{"lorc": 5}"#,             // lorc must be object/null
        r#"{"lorc": {"rnk": 4}}"#,    // typo'd nested key
        r#"{"name": "x"} trailing"#,  // trailing input
    ] {
        match QuantRecipe::from_json(bad) {
            Err(RecipeError::BadJson(_)) => {}
            other => panic!("{bad:?}: expected BadJson, got {other:?}"),
        }
    }
    // a validation failure surfaces through from_json too (the file is a
    // reproducibility artifact; loading must re-run the same gate)
    assert_eq!(
        QuantRecipe::from_json(r#"{"weight": "f16", "act": "f16", "layout": "packed"}"#)
            .unwrap_err(),
        RecipeError::PackedNeedsCodes
    );
}

#[test]
fn recipe_file_plus_flags_override_precedence() {
    // base artifact: w4a8 + M2:32 + cast + LoRC r4, packed x2
    let base = RecipeBuilder::new(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .cast_fp4_to_e5m2(true)
        .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
        .packed(2)
        .name("pinned")
        .build()
        .unwrap();
    let dir = std::env::temp_dir().join("zqfp_recipes_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pinned.json");
    std::fs::write(&path, base.to_json()).unwrap();
    let argv = |s: &[&str]| {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    };

    // no flags: the file wins over the per-command default
    let a = argv(&["--recipe", path.to_str().unwrap()]);
    let r = QuantRecipe::from_args(&a, "w16").unwrap();
    assert_eq!(r, base);
    assert!(a.finish().is_ok());

    // explicit flags beat the file, untouched fields survive
    let a = argv(&[
        "--recipe",
        path.to_str().unwrap(),
        "--constraint",
        "m1",
        "--lorc-rank",
        "16",
        "--gemv-threads",
        "4",
    ]);
    let r = QuantRecipe::from_args(&a, "w16").unwrap();
    assert_eq!(r.constraint, ScaleConstraint::M1, "flag beats file");
    assert_eq!(r.lorc.unwrap().rank, 16, "lorc knob adjusts the file's factors");
    assert_eq!(r.weights, WeightLayout::Packed { threads: 4 });
    assert!(r.cast_fp4_to_e5m2, "unoverridden file fields survive");
    assert_eq!(r.scheme, base.scheme);
    assert!(a.finish().is_ok());

    // off-switches un-pin what the file turned on: a packed artifact can
    // be served dense without hand-editing the JSON
    let a = argv(&["--recipe", path.to_str().unwrap(), "--dense", "--no-cast", "--no-lorc"]);
    let r = QuantRecipe::from_args(&a, "w16").unwrap();
    assert!(r.weights.is_dense());
    assert!(!r.cast_fp4_to_e5m2);
    assert!(r.lorc.is_none());
    assert!(a.finish().is_ok());

    // per-command default applies only when --recipe/--scheme are absent
    let r = QuantRecipe::from_args(&argv(&[]), "w4a8-fp-lorc").unwrap();
    assert_eq!(r, QuantRecipe::preset("w4a8-fp-lorc").unwrap());
}

#[test]
fn presets_serve_bit_identically_through_the_stack() {
    for arch in [Arch::Opt, Arch::Llama] {
        let ck = tiny_ck(arch);
        let window: Vec<u16> = (0..12).map(|i| (i * 7 % 48) as u16).collect();
        for name in PRESET_NAMES {
            let mut recipe = QuantRecipe::preset(name).unwrap();
            // toy dims: a few groups per row instead of one
            recipe.group_size = 16;
            let seqs = if recipe.needs_calibration() { calib(2, 8) } else { Vec::new() };
            let stack = ServingStack::build(&ck, &seqs, &recipe).unwrap();
            let model = stack.compile();
            let dense_logits = model.forward_alloc(&window);
            // the plan serves exactly the reference engine's bits over the
            // effective checkpoint
            let reference =
                Engine::with_opts(&stack.checkpoint, recipe.engine_opts()).forward(&window);
            assert_bit_identical(&reference, &dense_logits, &format!("{arch:?} {name} dense"));
            // quantized presets also serve packed, bit-identically
            if !matches!(recipe.scheme.weight, NumericFormat::F16) {
                let mut packed = recipe.clone();
                packed.weights = WeightLayout::Packed { threads: 1 };
                packed.validate().unwrap();
                let packed_logits =
                    stack.with_recipe(&packed).unwrap().compile().forward_alloc(&window);
                assert_bit_identical(
                    &dense_logits,
                    &packed_logits,
                    &format!("{arch:?} {name} packed"),
                );
            }
        }
    }
}

#[test]
fn stack_coordinator_serves_the_recipe() {
    // one preset end to end: recipe → stack → coordinator → scored request
    let ck = tiny_ck(Arch::Opt);
    let mut recipe = QuantRecipe::preset("w8a8-int").unwrap();
    recipe.group_size = 16;
    recipe.max_wait_ms = 0;
    let stack = ServingStack::build(&ck, &calib(2, 8), &recipe).unwrap();
    let model = stack.compile();
    let mut scratch = model.scratch();
    let window: Vec<u16> = (0..12).map(|i| (i * 5 % 48) as u16).collect();
    let direct = model.score_nll(&window, &mut scratch);
    let coord = stack.coordinator();
    let client = coord.client().unwrap();
    let w = window.clone();
    let h = std::thread::spawn(move || client.score(w).unwrap());
    coord.run().unwrap();
    assert_eq!(h.join().unwrap(), direct);
}

#[test]
fn speculate_summary_and_json_round_trip() {
    // The serving knobs a speculating deployment pins — kernel tier and the
    // nested draft recipe — must survive summary() (human-facing) and the
    // JSON round-trip (config-file-facing) without drifting.
    let draft = RecipeBuilder::new(Scheme::parse("w4a8-fp-fp").unwrap())
        .name("cheap-draft")
        .group_size(16)
        .use_gptq(false)
        .packed(2)
        .kernels(KernelTier::Fast)
        .build()
        .unwrap();
    let target = RecipeBuilder::new(Scheme::parse("w4a8-fp-fp").unwrap())
        .name("spec-target")
        .group_size(16)
        .use_gptq(false)
        .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
        .packed(1)
        .speculate(draft.clone(), 3)
        .build()
        .unwrap();

    // summary surfaces both knobs, on draft and target alike
    let s = target.summary();
    assert!(s.contains("kernels=oracle"), "target summary missing kernel tier: {s}");
    assert!(s.contains("speculate=cheap-draft/k3"), "target summary missing speculate: {s}");
    assert!(draft.summary().contains("kernels=fast"), "draft summary missing fast tier");
    assert!(!draft.summary().contains("speculate="), "non-speculating draft grew a speculate knob");

    // compact and pretty JSON both round-trip bit-exactly, draft included
    for text in [target.to_json(), target.to_json_pretty()] {
        let back = QuantRecipe::from_json(&text).unwrap();
        assert_eq!(back, target, "speculating recipe drifted through JSON");
        let sc = back.speculate.as_ref().unwrap();
        assert_eq!(*sc.draft, draft);
        assert_eq!(sc.k, 3);
        assert_eq!(sc.draft.kernel_tier, KernelTier::Fast);
    }

    // a preset name is accepted as draft shorthand in a recipe document
    // (the sparse doc's default scheme is W4A8, so the LoRC'd target is
    // strictly heavier than the plain w4a8-fp preset on the rank axis)
    let doc = r#"{
        "name": "from-doc",
        "group_size": 16,
        "lorc": {"rank": 4, "format": "fp8_e4m3"},
        "speculate": {"draft": "w4a8-fp", "k": 2}
    }"#;
    let from_doc = QuantRecipe::from_json(doc).unwrap();
    let sc = from_doc.speculate.as_ref().unwrap();
    assert_eq!(*sc.draft, QuantRecipe::preset("w4a8-fp").unwrap());
    assert_eq!(sc.k, 2);
    // and the shorthand round-trips through the expanded form
    assert_eq!(QuantRecipe::from_json(&from_doc.to_json()).unwrap(), from_doc);

    // field mutation after build still funnels through validate() on parse:
    // a draft that itself speculates serializes fine but is rejected typed
    let mut bad = from_doc.clone();
    bad.speculate = Some(SpeculateConfig { draft: Box::new(bad.clone()), k: 2 });
    assert!(matches!(
        QuantRecipe::from_json(&bad.to_json()),
        Err(RecipeError::SpeculateNested)
    ));
}
