//! The incremental-decode correctness contract:
//!
//! 1. `prefill + N × decode_step` over a window produces logits
//!    **bit-identical** to one full-recompute `forward` over that window —
//!    across both architectures, every activation `NumericFormat`, every
//!    prompt/decode split point, chunked prefill, and ring-capacity
//!    (`seq == max_seq`) sequences, including cache reuse after `reset`.
//! 2. Batched continuous decode (`decode_step_batch`) is bit-identical per
//!    sequence to solo decode — a sequence's logits cannot depend on its
//!    batch mates.
//! 3. An FP8-quantized cache deliberately leaves contract (1) but keeps
//!    *split-invariance*: where the prompt/decode boundary falls cannot
//!    change the logits, because rows are quantized independently of when
//!    they were appended.

use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::formats::{FpFormat, NumericFormat};
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::{CompiledModel, KvCache};
use zeroquant_fp::quant::Scheme;
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::Matrix;

/// Compile the plan the way the serving stack does: a W16 recipe (weights
/// untouched) with `fmt` activations through [`ServingStack::build`] — so
/// the incremental-decode contract is checked over the recipe → plan
/// wiring the coordinator itself uses.
fn stack_model(ck: &Checkpoint, fmt: NumericFormat) -> CompiledModel {
    let recipe = QuantRecipe::builder(Scheme { weight: NumericFormat::F16, activation: fmt })
        .build()
        .unwrap();
    ServingStack::build(ck, &[], &recipe).unwrap().compile()
}

fn tiny(arch: Arch) -> ModelConfig {
    ModelConfig {
        name: format!("kv-equiv-{}", arch.name()),
        arch,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 12,
    }
}

const ACT_FORMATS: [NumericFormat; 8] = [
    NumericFormat::F16,
    NumericFormat::FP8_E4M3,
    NumericFormat::FP8_E5M2,
    NumericFormat::FP4_E2M1,
    NumericFormat::FP4_E3M0,
    NumericFormat::INT8,
    NumericFormat::INT8_ASYM,
    NumericFormat::INT4,
];

fn bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

fn random_window(len: usize, vocab: usize, rng: &mut Rng) -> Vec<u16> {
    (0..len).map(|_| rng.below(vocab) as u16).collect()
}

/// Run `window` as `prefill(window[..split])` + one `decode_step` per
/// remaining token, asserting every produced logits row is bit-identical
/// to the corresponding row of `full`.
fn check_split(
    model: &CompiledModel,
    cache: &mut KvCache,
    window: &[u16],
    split: usize,
    full: &Matrix,
    what: &str,
) {
    let mut s = model.scratch();
    let pre = model.prefill(&window[..split], cache, &mut s).clone();
    assert_eq!(pre.rows, split, "{what}: prefill row count");
    for t in 0..split {
        assert_eq!(
            bits(pre.row(t)),
            bits(full.row(t)),
            "{what}: prefill row {t} of split {split}"
        );
    }
    for (off, &tok) in window[split..].iter().enumerate() {
        let t = split + off;
        let step = model.decode_step(tok, cache, &mut s);
        assert_eq!((step.rows, step.cols), (1, full.cols), "{what}: step shape");
        assert_eq!(bits(step.row(0)), bits(full.row(t)), "{what}: decode row {t} of split {split}");
    }
    assert_eq!(cache.len(), window.len(), "{what}: cache cursor");
}

#[test]
fn prefill_plus_decode_bit_identical_to_forward() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xCACE + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        for fmt in ACT_FORMATS {
            let model = stack_model(&ck, fmt);
            let mut s = model.scratch();
            let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
            let full = model.forward(&window, &mut s).clone();
            // literally every prompt/decode split of the window — the docs
            // promise as much (split == max_seq is the pure-prefill case)
            for split in 1..=cfg.max_seq {
                let mut cache = model.kv_cache();
                check_split(
                    &model,
                    &mut cache,
                    &window,
                    split,
                    &full,
                    &format!("{arch:?} act={}", fmt.name()),
                );
            }
        }
    }
}

#[test]
fn chunked_prefill_matches_single_shot() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xC0FFEE + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        for fmt in [NumericFormat::F16, NumericFormat::FP8_E4M3] {
            let model = stack_model(&ck, fmt);
            let mut s = model.scratch();
            let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
            let full = model.forward(&window, &mut s).clone();
            let mut cache = model.kv_cache();
            let mut done = 0usize;
            for chunk in [4usize, 5, 3] {
                let pre = model.prefill(&window[done..done + chunk], &mut cache, &mut s);
                for t in 0..chunk {
                    assert_eq!(
                        bits(pre.row(t)),
                        bits(full.row(done + t)),
                        "{arch:?} act={} chunked row {}",
                        fmt.name(),
                        done + t
                    );
                }
                done += chunk;
            }
            assert_eq!(cache.len(), cfg.max_seq);
        }
    }
}

#[test]
fn cache_reuse_after_reset_is_clean() {
    // Fill the ring to capacity, reset, and serve a different sequence
    // through the recycled rings (the coordinator's cache-pool pattern) —
    // stale rows must be invisible.
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0x5EED2 + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = stack_model(&ck, NumericFormat::F16);
        let mut s = model.scratch();
        let first = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        let second = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        let mut cache = model.kv_cache();
        model.prefill(&first, &mut cache, &mut s);
        assert_eq!(cache.remaining(), 0, "ring at capacity");
        cache.reset();
        assert_eq!(cache.remaining(), cfg.max_seq);
        let full = model.forward(&second, &mut s).clone();
        check_split(&model, &mut cache, &second, 7, &full, &format!("{arch:?} reused ring"));
    }
}

#[test]
fn quantized_cache_is_split_invariant_and_actually_quantizes() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xFB8 + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = stack_model(&ck, NumericFormat::F16);
        let mut s = model.scratch();
        let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        let exact = model.forward(&window, &mut s).clone();

        // single-shot prefill through an FP8 cache
        let mut c_once = model.kv_cache_quantized(FpFormat::E4M3);
        let once = model.prefill(&window, &mut c_once, &mut s).clone();

        // quantization must actually engage (the cache is not a no-op) …
        assert!(
            once.data.iter().zip(&exact.data).any(|(a, b)| a.to_bits() != b.to_bits()),
            "{arch:?}: FP8 cache produced bit-identical logits — quantization inactive?"
        );
        // … logits stay finite …
        assert!(once.data.iter().all(|x| x.is_finite()), "{arch:?}: FP8 cache logits finite");

        // … and every prompt/decode split reproduces the same bits
        // (rows are quantized independently of when they were appended).
        for split in 1..=cfg.max_seq {
            let mut cache = model.kv_cache_quantized(FpFormat::E4M3);
            check_split(
                &model,
                &mut cache,
                &window,
                split,
                &once,
                &format!("{arch:?} fp8-kv split {split}"),
            );
        }
    }
}

#[test]
fn page_boundary_splits_are_bit_identical_to_forward() {
    // Paged-pool extension of contract (1): prompt/decode splits landing
    // exactly on, one before, and one after a page boundary (and a later
    // boundary), plus chunked prefill whose chunks straddle a page edge —
    // all bit-identical to the full-recompute forward. tests/kv_paged.rs
    // carries the exhaustive paged-vs-ring matrix; this pins the boundary
    // cases into the incremental-decode contract itself.
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xB0DA + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = stack_model(&ck, NumericFormat::F16);
        let mut s = model.scratch();
        let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        let full = model.forward(&window, &mut s).clone();
        for p in [3usize, 4] {
            for split in [p - 1, p, p + 1, 2 * p] {
                let mut pool = model.kv_page_pool(p, 0, None);
                let mut cache = pool.new_cache();
                assert!(pool.reserve(&mut cache, window.len()), "reserve the whole window");
                check_split(
                    &model,
                    &mut cache,
                    &window,
                    split,
                    &full,
                    &format!("{arch:?} page={p}"),
                );
                pool.release(&mut cache);
                assert_eq!(pool.free_pages(), pool.total_pages(), "{arch:?} page={p}");
            }
        }
        // chunked prefill over 4-position pages with chunk boundaries at
        // 3 and 7 — both straddle a page edge (4, 8)
        let mut pool = model.kv_page_pool(4, 0, None);
        let mut cache = pool.new_cache();
        assert!(pool.reserve(&mut cache, window.len()));
        let mut done = 0usize;
        for chunk in [3usize, 4, 5] {
            let pre = model.prefill(&window[done..done + chunk], &mut cache, &mut s);
            for t in 0..chunk {
                assert_eq!(
                    bits(pre.row(t)),
                    bits(full.row(done + t)),
                    "{arch:?}: straddling chunk row {}",
                    done + t
                );
            }
            done += chunk;
        }
        assert_eq!(cache.len(), cfg.max_seq);
    }
}

#[test]
fn batched_decode_bit_identical_to_solo_decode() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xBA7C4 + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = stack_model(&ck, NumericFormat::FP8_E4M3);
        let mut s = model.scratch();
        // three sequences at different positions in their own windows
        let prompts: [Vec<u16>; 3] = [
            random_window(2, cfg.vocab_size, &mut rng),
            random_window(5, cfg.vocab_size, &mut rng),
            random_window(3, cfg.vocab_size, &mut rng),
        ];
        let steps: Vec<Vec<u16>> =
            (0..4).map(|_| random_window(3, cfg.vocab_size, &mut rng)).collect();

        let mut solo: Vec<KvCache> = (0..3).map(|_| model.kv_cache()).collect();
        let mut batch: Vec<KvCache> = (0..3).map(|_| model.kv_cache()).collect();
        for i in 0..3 {
            model.prefill(&prompts[i], &mut solo[i], &mut s);
            model.prefill(&prompts[i], &mut batch[i], &mut s);
        }
        for step in &steps {
            let mut expect: Vec<Vec<u32>> = Vec::new();
            for i in 0..3 {
                expect.push(bits(model.decode_step(step[i], &mut solo[i], &mut s).row(0)));
            }
            let got = model.decode_step_batch(step, &mut batch, &mut s);
            assert_eq!(got.rows, 3);
            for i in 0..3 {
                assert_eq!(bits(got.row(i)), expect[i], "{arch:?} batched row {i}");
            }
        }
        for i in 0..3 {
            assert_eq!(solo[i].len(), batch[i].len());
        }
    }
}
