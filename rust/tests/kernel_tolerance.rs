//! The fast tier's admission gate — the differential tolerance contract
//! between [`FastKernels`] and the bit-exact [`OracleKernels`] reference:
//!
//! 1. **Per-element ULP bound at GEMV scale.** On every adversarial case
//!    of the shared generator (`tests/common`: zero/subnormal/non-finite
//!    group scales, all-negative rows, lane-unfriendly shapes, LoRC fold),
//!    each fast output element is within `MAX_ULP` ULPs of the oracle —
//!    or, where cancellation makes result-relative ULPs meaningless,
//!    within `MAX_ULP` ULPs *at the problem's scale* `‖x_row‖·‖ŵ_row‖`.
//!    Non-finite elements must poison identically, not approximately.
//! 2. **Model-level drift bounds.** Through full packed plans (both archs,
//!    odd dims, LoRC), logits drift stays inside a relative band and the
//!    corpus NLL moves by ≤ 1e-4 relative — quantization claims measured
//!    under the oracle transfer to the fast tier.
//! 3. **Greedy-decode token parity.** ≥ 64 KV-cached greedy tokens are
//!    identical between tiers — serving output is unchanged, not merely
//!    close.
//! 4. **Pool determinism.** The fast tier is bit-identical to itself
//!    across worker counts {1, 2, 4}, at kernel scale and through the
//!    compiled plan — the persistent pool shards work without touching
//!    the arithmetic.
//! 5. **Dense layout bit-identity.** On the dense layout the tiers share
//!    the reference axpy kernel, so fast-vs-oracle is bit-identical there.

mod common;

use common::{assert_bit_identical, calib, model_cfg};
use zeroquant_fp::engine::KernelTier;
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::kernels::{FastKernels, Kernels, OracleKernels};
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::Scheme;
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::packed_matmul::GemvScratch;
use zeroquant_fp::tensor::Matrix;

/// The contract's ULP budget per GEMV element (either arm of the gate).
const MAX_ULP: i64 = 4;
/// The contract's relative NLL drift bound.
const MAX_NLL_DRIFT: f64 = 1e-4;
/// Greedy generations must match for at least this many tokens.
const PARITY_TOKENS: usize = 64;

// ---- the hybrid ULP gate ------------------------------------------------

/// Map a finite f32 onto the integer ULP line (negatives mirrored below
/// zero, so `ulp_index(a) - ulp_index(b)` counts representable values
/// between `a` and `b`; ±0 coincide).
fn ulp_index(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        -((b & 0x7FFF_FFFF) as i64)
    } else {
        b as i64
    }
}

fn ulp_diff(a: f32, b: f32) -> i64 {
    (ulp_index(a) - ulp_index(b)).abs()
}

/// The spacing of representable values at magnitude `scale`.
fn ulp_at(scale: f32) -> f32 {
    let a = scale.abs().max(f32::MIN_POSITIVE);
    f32::from_bits(a.to_bits() + 1) - a
}

/// The tolerance contract for one element: equal-kind non-finites pass,
/// finite values pass within `MAX_ULP` ULPs of each other **or** within
/// `MAX_ULP` ULPs at the problem's scale (the summation-error bound when
/// cancellation shrinks the result far below the terms).
fn assert_within_gate(a: f32, b: f32, scale: f32, what: &str) {
    if a.is_nan() && b.is_nan() {
        return;
    }
    if a.is_infinite() || b.is_infinite() || a.is_nan() || b.is_nan() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: non-finite values must poison identically (oracle={a} fast={b})"
        );
        return;
    }
    let ud = ulp_diff(a, b);
    if ud <= MAX_ULP {
        return;
    }
    let tol = MAX_ULP as f32 * ulp_at(scale);
    assert!(
        (a - b).abs() <= tol,
        "{what}: oracle={a} fast={b} ulp_diff={ud} |Δ|={} > {tol} at scale {scale}",
        (a - b).abs()
    );
}

fn l2(row: &[f32]) -> f32 {
    row.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt() as f32
}

// ---- kernel-scale gates -------------------------------------------------

fn run_tier(k: &dyn Kernels, case: &common::GemvCase) -> Matrix {
    let e2 = case.lorc.as_ref().map_or(0, |l| l.e2_elems());
    let mut out = Matrix::zeros(case.x.rows, case.w.rows);
    let mut s = GemvScratch::sized(case.w.cols, e2);
    k.packed_gemv(&case.x, &case.w, case.lorc.as_ref(), &mut out, &mut s);
    out
}

#[test]
fn fast_gemv_within_ulp_gate_on_adversarial_cases() {
    let oracle = OracleKernels::new(1);
    let fast = FastKernels::new(1);
    for case in common::gemv_cases(0xFA57) {
        let want = run_tier(&oracle, &case);
        let got = run_tier(&fast, &case);
        // the gate's scale: ‖x_row‖·‖ŵ_row‖ over the effective (decoded,
        // LoRC-folded) weight — an upper bound on the dot's term mass
        let eff = common::effective_dense(&case.w, case.lorc.as_ref());
        let xn: Vec<f32> = (0..case.x.rows).map(|r| l2(case.x.row(r))).collect();
        let wn: Vec<f32> = (0..eff.rows).map(|j| l2(eff.row(j))).collect();
        for r in 0..want.rows {
            for j in 0..want.cols {
                assert_within_gate(
                    want.data[r * want.cols + j],
                    got.data[r * want.cols + j],
                    xn[r] * wn[j],
                    &format!("case '{}' element [{r},{j}]", case.name),
                );
            }
        }
    }
}

#[test]
fn fast_gemv_bit_identical_across_pool_sizes() {
    for case in common::gemv_cases(0xD00F) {
        let solo = run_tier(&FastKernels::new(1), &case);
        for threads in [2usize, 4] {
            let pooled = run_tier(&FastKernels::new(threads), &case);
            assert_bit_identical(
                &solo,
                &pooled,
                &format!("case '{}' threads={threads}", case.name),
            );
        }
    }
}

// ---- compiled-plan gates ------------------------------------------------

fn recipe(tier: KernelTier, threads: usize, lorc: bool) -> QuantRecipe {
    let mut b = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .group_size(16)
        .use_gptq(false)
        .packed(threads)
        .kernels(tier);
    if lorc {
        b = b.lorc(LorcConfig { rank: 2, factor_format: NumericFormat::FP8_E4M3 });
    }
    b.build().unwrap()
}

/// Compile the oracle plan and its fast-tier twin over one quantization of
/// `ck` (same stack, same sidecar bits — the tiers are the only delta).
fn twins(ck: &Checkpoint, lorc: bool) -> (CompiledModel, CompiledModel) {
    let stack = zeroquant_fp::coordinator::ServingStack::build(
        ck,
        &calib(3, 8, ck.config.vocab_size),
        &recipe(KernelTier::Oracle, 1, lorc),
    )
    .unwrap();
    let oracle = stack.compile();
    let fast = stack.with_recipe(&recipe(KernelTier::Fast, 1, lorc)).unwrap().compile();
    (oracle, fast)
}

/// Mean NLL of `tokens` under the model (f64 log-sum-exp).
fn nll(m: &CompiledModel, tokens: &[u16]) -> f64 {
    let mut s = m.scratch();
    let logits = m.forward(tokens, &mut s);
    let mut total = 0.0f64;
    for t in 0..tokens.len() - 1 {
        let row = &logits.data[t * logits.cols..(t + 1) * logits.cols];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse = mx + row.iter().map(|&v| (v as f64 - mx).exp()).sum::<f64>().ln();
        total += lse - row[tokens[t + 1] as usize] as f64;
    }
    total / (tokens.len() - 1) as f64
}

fn tolerance_shapes() -> Vec<(ModelConfig, bool, &'static str)> {
    let mut shapes = Vec::new();
    for arch in [Arch::Opt, Arch::Llama] {
        // even dims, odd dims (trailing nibble + 8-lane tail), LoRC fold
        shapes.push((model_cfg(arch, "tol-even", 24, 3, 48, 12), false, "even"));
        shapes.push((model_cfg(arch, "tol-odd", 25, 5, 49, 12), false, "odd"));
        shapes.push((model_cfg(arch, "tol-lorc", 24, 3, 48, 12), true, "lorc"));
    }
    shapes
}

#[test]
fn fast_plan_keeps_logits_and_nll_within_drift_bounds() {
    for (cfg, lorc, tag) in tolerance_shapes() {
        let mut rng = Rng::seeded(0x701 + cfg.arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let (oracle, fast) = twins(&ck, lorc);
        let mut os = oracle.scratch();
        let mut fs = fast.scratch();
        let what = format!("{:?} {tag}", cfg.arch);
        for seq in [1usize, 5, cfg.max_seq] {
            let tokens: Vec<u16> =
                (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
            let want = oracle.forward(&tokens, &mut os).clone();
            let got = fast.forward(&tokens, &mut fs);
            assert_eq!((want.rows, want.cols), (got.rows, got.cols), "{what}: shape");
            // logits drift: relative to each row's dominant magnitude —
            // per-linear ULP noise composed over layers, still tiny
            for r in 0..want.rows {
                let row = &want.data[r * want.cols..(r + 1) * want.cols];
                let scale = row.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-3);
                for c in 0..want.cols {
                    let (a, b) = (row[c], got.data[r * got.cols + c]);
                    assert!(
                        (a - b).abs() <= 1e-4 * scale,
                        "{what} seq={seq} logit [{r},{c}]: oracle={a} fast={b} scale={scale}"
                    );
                }
            }
        }
        // NLL drift over held-out streams
        for (i, tokens) in calib(4, 10, cfg.vocab_size).iter().enumerate() {
            let base = nll(&oracle, tokens);
            let drift = (nll(&fast, tokens) - base).abs();
            assert!(
                drift <= MAX_NLL_DRIFT * base.abs().max(1.0),
                "{what} stream {i}: NLL drift {drift} vs base {base}"
            );
        }
    }
}

fn argmax_last(m: &Matrix) -> u16 {
    let row = &m.data[(m.rows - 1) * m.cols..];
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as u16
}

/// KV-cached greedy generation: prefill the prompt, then decode `steps`
/// tokens taking the argmax at every step.
fn greedy(m: &CompiledModel, prompt: &[u16], steps: usize) -> Vec<u16> {
    let mut s = m.scratch();
    let mut cache = m.kv_cache();
    let mut out = Vec::with_capacity(steps);
    let mut next = argmax_last(m.prefill(prompt, &mut cache, &mut s));
    for _ in 0..steps {
        out.push(next);
        next = argmax_last(m.decode_step(next, &mut cache, &mut s));
    }
    out
}

#[test]
fn fast_plan_greedy_decode_token_parity() {
    for arch in [Arch::Opt, Arch::Llama] {
        // max_seq 80: an 8-token prompt plus 64 decode steps with headroom
        let cfg = model_cfg(arch, "tol-gen", 24, 3, 48, 80);
        let mut rng = Rng::seeded(0x6E2E + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let (oracle, fast) = twins(&ck, false);
        let prompt: Vec<u16> = (0..8).map(|_| rng.below(cfg.vocab_size) as u16).collect();
        let want = greedy(&oracle, &prompt, PARITY_TOKENS);
        let got = greedy(&fast, &prompt, PARITY_TOKENS);
        assert_eq!(want.len(), PARITY_TOKENS);
        assert_eq!(
            want, got,
            "{arch:?}: greedy generations must be token-identical across tiers"
        );
    }
}

#[test]
fn fast_plan_bit_identical_across_pool_sizes() {
    let cfg = model_cfg(Arch::Llama, "tol-pool", 24, 3, 48, 12);
    let mut rng = Rng::seeded(0xB001);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let stack = zeroquant_fp::coordinator::ServingStack::build(
        &ck,
        &calib(3, 8, cfg.vocab_size),
        &recipe(KernelTier::Fast, 1, false),
    )
    .unwrap();
    let solo = stack.compile();
    let tokens: Vec<u16> = (0..10).map(|i| (i * 7 % cfg.vocab_size) as u16).collect();
    let want = solo.forward_alloc(&tokens);
    for threads in [2usize, 4] {
        let pooled =
            stack.with_recipe(&recipe(KernelTier::Fast, threads, false)).unwrap().compile();
        assert_bit_identical(
            &want,
            &pooled.forward_alloc(&tokens),
            &format!("fast plan threads={threads}"),
        );
    }
}

#[test]
fn fast_tier_is_bit_identical_on_the_dense_layout() {
    // On the dense layout both tiers share the reference axpy kernel and
    // the default norm/softmax methods — the differential gate tightens to
    // full bit-identity.
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = model_cfg(arch, "tol-dense", 24, 3, 48, 12);
        let mut rng = Rng::seeded(0xDE45 + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let mk = |tier: KernelTier| {
            QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
                .group_size(16)
                .use_gptq(false)
                .kernels(tier)
                .build()
                .unwrap()
        };
        let stack = zeroquant_fp::coordinator::ServingStack::build(
            &ck,
            &calib(3, 8, cfg.vocab_size),
            &mk(KernelTier::Oracle),
        )
        .unwrap();
        let oracle = stack.compile();
        let fast = stack.with_recipe(&mk(KernelTier::Fast)).unwrap().compile();
        let tokens: Vec<u16> = (0..cfg.max_seq).map(|i| (i * 5 % 48) as u16).collect();
        assert_bit_identical(
            &oracle.forward_alloc(&tokens),
            &fast.forward_alloc(&tokens),
            &format!("{arch:?} dense layout"),
        );
    }
}
