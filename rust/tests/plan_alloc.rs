//! Steady-state decode through the compiled plan performs ZERO heap
//! allocations — asserted with a counting global allocator. Covers both
//! execution shapes: full-window `forward` scoring, and the KV-cached
//! serving loop (`reset` → `prefill` → `decode_step`/`decode_step_batch`)
//! once the arena, the caches and the cache pool are warm — for the dense
//! f32 weight layout, the bit-packed layout (whose fused GEMV decodes
//! weight rows into the arena's strip; `threads == 1`, the threaded shard
//! path spawns by design), **and** the packed+LoRC layout (whose decoded-E₂
//! and error-row strips also live in the arena).
//!
//! This file holds exactly one test: the allocation counter is global, so
//! any concurrently running test in the same binary would pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::EngineOpts;
use zeroquant_fp::formats::{FpFormat, NumericFormat};
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

// SAFETY: defers entirely to `System`; only a counter is layered on top.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_is_allocation_free() {
    for (arch, fmt) in [
        (Arch::Opt, NumericFormat::F16),
        (Arch::Opt, NumericFormat::FP8_E4M3),
        (Arch::Opt, NumericFormat::INT8),
        (Arch::Llama, NumericFormat::FP8_E4M3),
    ] {
        let cfg = ModelConfig {
            name: "alloc-test".into(),
            arch,
            vocab_size: 48,
            d_model: 24,
            n_heads: 3,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
        };
        let mut rng = Rng::seeded(0xA110C);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let opts = EngineOpts::with_act(fmt);
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        let long: Vec<u16> = (0..cfg.max_seq).map(|_| rng.below(48) as u16).collect();
        let short: Vec<u16> = long[..5].to_vec();

        // Warm the arena at the largest shape that will be used.
        std::hint::black_box(model.forward(&long, &mut scratch));
        std::hint::black_box(model.forward(&short, &mut scratch));

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..8 {
            std::hint::black_box(model.forward(&long, &mut scratch));
            std::hint::black_box(model.forward(&short, &mut scratch));
            std::hint::black_box(model.score_nll(&long, &mut scratch));
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state decode allocated ({arch:?}, act={})",
            fmt.name()
        );

        // ---- the KV-cached serving loop: reset → prefill → decode ------
        // (single-sequence and continuous-batching shapes; the caches play
        // the coordinator's recycled-pool role)
        let mut cache = model.kv_cache();
        let mut caches = vec![model.kv_cache(), model.kv_cache()];
        let prompt = &long[..6];
        let gen = &long[6..10];
        let toks = [long[0], long[1]];
        let mut serve_pass = |cache: &mut zeroquant_fp::plan::KvCache,
                              caches: &mut Vec<zeroquant_fp::plan::KvCache>,
                              scratch: &mut zeroquant_fp::plan::DecodeScratch| {
            cache.reset();
            std::hint::black_box(model.prefill(prompt, cache, scratch));
            for &t in gen {
                std::hint::black_box(model.decode_step(t, cache, scratch));
            }
            for c in caches.iter_mut() {
                c.reset();
                std::hint::black_box(model.prefill(&prompt[..3], c, scratch));
            }
            for _ in 0..3 {
                std::hint::black_box(model.decode_step_batch(&toks, caches, scratch));
            }
        };
        serve_pass(&mut cache, &mut caches, &mut scratch); // warm
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..6 {
            serve_pass(&mut cache, &mut caches, &mut scratch);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "kv serving loop allocated ({arch:?}, act={})",
            fmt.name()
        );

        // ---- the paged pool: page churn is allocation-free -------------
        // Pages are minted eagerly at pool construction and page tables
        // pre-size to the deepest walk, so once a cache has been through
        // one full admit → prefill → page-at-a-time decode → release
        // cycle, every later cycle just moves PageBufs between the free
        // list and the page table — exact and FP8-quantizing pools alike.
        let mut pool = model.kv_page_pool(4, 0, None);
        let mut qpool = model.kv_page_pool(4, 0, Some(FpFormat::E4M3));
        let mut pcache = pool.new_cache();
        let mut qcache = qpool.new_cache();
        let mut paged_pass = |pool: &mut zeroquant_fp::plan::KvPagePool,
                              cache: &mut zeroquant_fp::plan::KvCache,
                              scratch: &mut zeroquant_fp::plan::DecodeScratch| {
            assert!(pool.reserve(cache, prompt.len()));
            std::hint::black_box(model.prefill(prompt, cache, scratch));
            for &t in gen {
                assert!(pool.reserve(cache, 1));
                std::hint::black_box(model.decode_step(t, cache, scratch));
            }
            pool.release(cache);
        };
        paged_pass(&mut pool, &mut pcache, &mut scratch); // warm
        paged_pass(&mut qpool, &mut qcache, &mut scratch);
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..6 {
            paged_pass(&mut pool, &mut pcache, &mut scratch);
            paged_pass(&mut qpool, &mut qcache, &mut scratch);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "paged kv reserve/release churn allocated ({arch:?}, act={})",
            fmt.name()
        );
    }

    // ---- the packed weight layout: same contract, decoded weights ------
    // Quantize (RTN) to get codes, compile the packed plan, and require
    // the identical zero-allocation steady state for full-window forwards
    // and the KV-cached serving loop.
    let cfg = ModelConfig {
        name: "alloc-test-packed".into(),
        arch: Arch::Llama,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 16,
    };
    let mut rng = Rng::seeded(0xA110D);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M2 { rows: 8 })
        .use_gptq(false) // RTN needs no calibration passes
        .packed(1)
        .build()
        .unwrap();
    let model = ServingStack::build(&ck, &[], &recipe).unwrap().compile();
    let mut scratch = model.scratch();
    let long: Vec<u16> = (0..cfg.max_seq).map(|_| rng.below(48) as u16).collect();
    let short: Vec<u16> = long[..5].to_vec();

    std::hint::black_box(model.forward(&long, &mut scratch));
    std::hint::black_box(model.forward(&short, &mut scratch));
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..8 {
        std::hint::black_box(model.forward(&long, &mut scratch));
        std::hint::black_box(model.forward(&short, &mut scratch));
        std::hint::black_box(model.score_nll(&long, &mut scratch));
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(after - before, 0, "packed steady-state decode allocated");

    let mut cache = model.kv_cache();
    let mut caches = vec![model.kv_cache(), model.kv_cache()];
    let prompt = &long[..6];
    let gen = &long[6..10];
    let toks = [long[0], long[1]];
    let mut serve_pass = |cache: &mut zeroquant_fp::plan::KvCache,
                          caches: &mut Vec<zeroquant_fp::plan::KvCache>,
                          scratch: &mut zeroquant_fp::plan::DecodeScratch| {
        cache.reset();
        std::hint::black_box(model.prefill(prompt, cache, scratch));
        for &t in gen {
            std::hint::black_box(model.decode_step(t, cache, scratch));
        }
        for c in caches.iter_mut() {
            c.reset();
            std::hint::black_box(model.prefill(&prompt[..3], c, scratch));
        }
        for _ in 0..3 {
            std::hint::black_box(model.decode_step_batch(&toks, caches, scratch));
        }
    };
    serve_pass(&mut cache, &mut caches, &mut scratch); // warm
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..6 {
        serve_pass(&mut cache, &mut caches, &mut scratch);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(after - before, 0, "packed kv serving loop allocated");

    // ---- packed + LoRC: the factor decode/error strips live in the ----
    // arena (DecodeScratch's GEMV strips, sized by CompiledModel::scratch),
    // so the compensated decode loop is just as allocation-free.
    let cfg = ModelConfig {
        name: "alloc-test-lorc".into(),
        arch: Arch::Opt,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 16,
    };
    let mut rng = Rng::seeded(0xA110E);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M1)
        .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
        .use_gptq(false)
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(&ck, &[], &recipe).unwrap();
    assert!(!stack.sidecar.is_empty(), "lorc run must keep its sidecar");
    let model = stack.compile();
    let mut scratch = model.scratch();
    let long: Vec<u16> = (0..cfg.max_seq).map(|_| rng.below(48) as u16).collect();
    let short: Vec<u16> = long[..5].to_vec();

    std::hint::black_box(model.forward(&long, &mut scratch));
    std::hint::black_box(model.forward(&short, &mut scratch));
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..8 {
        std::hint::black_box(model.forward(&long, &mut scratch));
        std::hint::black_box(model.forward(&short, &mut scratch));
        std::hint::black_box(model.score_nll(&long, &mut scratch));
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(after - before, 0, "packed+lorc steady-state decode allocated");

    let mut cache = model.kv_cache();
    let mut caches = vec![model.kv_cache(), model.kv_cache()];
    let prompt = &long[..6];
    let gen = &long[6..10];
    let toks = [long[0], long[1]];
    let mut serve_pass = |cache: &mut zeroquant_fp::plan::KvCache,
                          caches: &mut Vec<zeroquant_fp::plan::KvCache>,
                          scratch: &mut zeroquant_fp::plan::DecodeScratch| {
        cache.reset();
        std::hint::black_box(model.prefill(prompt, cache, scratch));
        for &t in gen {
            std::hint::black_box(model.decode_step(t, cache, scratch));
        }
        for c in caches.iter_mut() {
            c.reset();
            std::hint::black_box(model.prefill(&prompt[..3], c, scratch));
        }
        for _ in 0..3 {
            std::hint::black_box(model.decode_step_batch(&toks, caches, scratch));
        }
    };
    serve_pass(&mut cache, &mut caches, &mut scratch); // warm
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..6 {
        serve_pass(&mut cache, &mut caches, &mut scratch);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(after - before, 0, "packed+lorc kv serving loop allocated");
}
