//! Steady-state decode through the compiled plan performs ZERO heap
//! allocations — asserted with a counting global allocator.
//!
//! This file holds exactly one test: the allocation counter is global, so
//! any concurrently running test in the same binary would pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use zeroquant_fp::engine::EngineOpts;
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::ActQuantConfig;
use zeroquant_fp::rng::Rng;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

// SAFETY: defers entirely to `System`; only a counter is layered on top.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_is_allocation_free() {
    for (arch, fmt) in [
        (Arch::Opt, NumericFormat::F16),
        (Arch::Opt, NumericFormat::FP8_E4M3),
        (Arch::Opt, NumericFormat::INT8),
        (Arch::Llama, NumericFormat::FP8_E4M3),
    ] {
        let cfg = ModelConfig {
            name: "alloc-test".into(),
            arch,
            vocab_size: 48,
            d_model: 24,
            n_heads: 3,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
        };
        let mut rng = Rng::seeded(0xA110C);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        let long: Vec<u16> = (0..cfg.max_seq).map(|_| rng.below(48) as u16).collect();
        let short: Vec<u16> = long[..5].to_vec();

        // Warm the arena at the largest shape that will be used.
        std::hint::black_box(model.forward(&long, &mut scratch));
        std::hint::black_box(model.forward(&short, &mut scratch));

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..8 {
            std::hint::black_box(model.forward(&long, &mut scratch));
            std::hint::black_box(model.forward(&short, &mut scratch));
            std::hint::black_box(model.score_nll(&long, &mut scratch));
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state decode allocated ({arch:?}, act={})",
            fmt.name()
        );
    }
}
