//! The session subsystem's acceptance gate (ISSUE 10's tentpole):
//!
//! 1. A multi-turn session is **bit-identical** to the equivalent
//!    one-shot generations — turn N+1 prefills only the token delta over
//!    the resident KV cache, yet produces exactly the tokens a fresh
//!    full-prompt prefill would. Checked across both architectures, ring
//!    and paged caches, greedy and seeded-sampling decoding.
//! 2. Fork duplicates a dialog position (src and dst answer the same
//!    delta identically), revert rewinds it (a re-run after revert
//!    reproduces the first run), and the paged pool's books
//!    (`free + resident + leaked == total`) balance after the dust
//!    settles.
//! 3. Capacity-bounded LRU eviction is invisible to clients: an evicted
//!    session's next turn transparently re-prefills from the committed
//!    history (counted in `session_restores`) and still matches the
//!    greedy reference bit for bit.
//! 4. Seeded sampling draws from a per-position prefix hash, so outputs
//!    are reproducible run-to-run and invariant to batch composition.
//! 5. `turn_stream` delivers every decoded token as a `Token` event
//!    before the single terminal `Done`, and the streamed prefix equals
//!    the final tokens.

use std::sync::mpsc::sync_channel;
use std::time::Duration;

use zeroquant_fp::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, SamplingConfig, ScoreBackend, ServeError,
    ServeReport, TurnEvent, DEFAULT_MAX_SESSIONS,
};
use zeroquant_fp::engine::EngineOpts;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::{argmax, CompiledModel};
use zeroquant_fp::rng::Rng;

const VOCAB: usize = 48;

fn ck(arch: Arch, seed: u64) -> Checkpoint {
    let cfg = ModelConfig {
        name: format!("sessions-{}", arch.name()),
        arch,
        vocab_size: VOCAB,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 16,
    };
    let mut rng = Rng::seeded(seed);
    Checkpoint::random(&cfg, &mut rng)
}

fn cfg(
    ck: Checkpoint,
    page: usize,
    sampling: SamplingConfig,
    max_sessions: usize,
) -> CoordinatorConfig {
    CoordinatorConfig {
        backend: ScoreBackend::Compiled,
        ck,
        opts: EngineOpts::default(),
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::ZERO },
        kv_quant: None,
        sidecar: None,
        queue_depth: 64,
        deadline: None,
        faults: None,
        speculate: None,
        kv_page_positions: page,
        kv_budget_bytes: 0, // auto (ring-equivalent) budget when paged
        sampling,
        max_sessions,
    }
}

fn run_within(coord: Coordinator, secs: u64) -> ServeReport {
    let (tx, rx) = sync_channel(1);
    let h = std::thread::spawn(move || {
        let _ = tx.send(coord.run());
    });
    let report = rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("serving loop must terminate within the watchdog timeout")
        .expect("serving loop must return a report, not an error");
    h.join().unwrap();
    report
}

fn assert_books_balance(report: &ServeReport) {
    if report.kv_pages_total > 0 {
        assert_eq!(
            report.kv_pages_free + report.kv_pages_resident + report.kv_pages_leaked,
            report.kv_pages_total,
            "page books must balance"
        );
        assert_eq!(report.kv_pages_leaked, 0, "sessions must not leak pages");
    }
}

fn greedy_reference(model: &CompiledModel, prompt: &[u16], max_new: usize) -> Vec<u16> {
    let mut scratch = model.scratch();
    let mut cache = model.kv_cache();
    let mut out = Vec::with_capacity(max_new);
    let logits = model.prefill(prompt, &mut cache, &mut scratch);
    let mut tok = argmax(logits.row(prompt.len() - 1)) as u16;
    out.push(tok);
    for _ in 1..max_new {
        let logits = model.decode_step(tok, &mut cache, &mut scratch);
        tok = argmax(logits.row(0)) as u16;
        out.push(tok);
    }
    out
}

fn toks(len: usize, salt: usize) -> Vec<u16> {
    (0..len).map(|k| ((salt * 11 + k * 7 + 3) % VOCAB) as u16).collect()
}

/// Drive one coordinator with a two-turn session and the equivalent pair
/// of one-shot generations, asserting token-for-token identity. Valid at
/// any temperature: positional draws hash the seed plus the token
/// prefix, so a delta prefill and a full prefill sample identically.
fn session_matches_oneshot(config: CoordinatorConfig) -> ServeReport {
    let coord = Coordinator::new(config);
    let gc = coord.gen_client().unwrap();
    let sc = coord.session_client().unwrap();
    let h = std::thread::spawn(move || {
        let p1 = toks(4, 1);
        let p2 = toks(3, 2);

        // one-shot references through the same serving loop
        let ref1 = gc.generate(p1.clone(), 3).unwrap();
        let mut full2 = p1.clone();
        full2.extend_from_slice(&ref1.tokens);
        full2.extend_from_slice(&p2);
        let ref2 = gc.generate(full2.clone(), 3).unwrap();

        // the session, one delta at a time
        sc.open("chat").unwrap();
        let g1 = sc.turn("chat", p1.clone(), 3).unwrap();
        assert_eq!(g1.tokens, ref1.tokens, "turn 1 must match the one-shot");
        assert_eq!(g1.prompt_len, p1.len());
        let g2 = sc.turn("chat", p2.clone(), 3).unwrap();
        assert_eq!(g2.tokens, ref2.tokens, "turn 2 (delta prefill) must match the one-shot");
        assert_eq!(g2.prompt_len, full2.len(), "turn 2 spans the whole committed history");

        let mut want_hist = full2;
        want_hist.extend_from_slice(&g2.tokens);
        assert_eq!(sc.tokens("chat").unwrap(), want_hist, "committed history drifted");
        sc.close("chat").unwrap();
    });
    let report = run_within(coord, 120);
    h.join().unwrap();
    assert_eq!(report.sessions_active, 0, "closed session must not linger");
    assert_books_balance(&report);
    report
}

#[test]
fn multi_turn_equals_one_shot_greedy_ring_and_paged_both_archs() {
    for arch in [Arch::Opt, Arch::Llama] {
        for page in [0usize, 4] {
            let report = session_matches_oneshot(cfg(
                ck(arch, 0xBEEF),
                page,
                SamplingConfig::default(),
                DEFAULT_MAX_SESSIONS,
            ));
            assert!(
                report.streamed_tokens >= 6,
                "{arch:?} page={page}: both turns' tokens must flow through the stream"
            );
        }
    }
}

#[test]
fn multi_turn_equals_one_shot_with_seeded_sampling() {
    let sampling = SamplingConfig { temperature: 0.8, top_k: 8, top_p: 0.9, seed: 42 };
    for arch in [Arch::Opt, Arch::Llama] {
        for page in [0usize, 4] {
            session_matches_oneshot(cfg(ck(arch, 0xFEED), page, sampling, DEFAULT_MAX_SESSIONS));
        }
    }
}

/// `temperature: 0` must be the greedy path bit for bit, whatever the
/// other knobs say — checked against a hand-rolled prefill/decode loop,
/// not just against the coordinator's own one-shot path.
#[test]
fn temperature_zero_is_bitwise_greedy() {
    let ck = ck(Arch::Opt, 0xA11CE);
    let model = CompiledModel::compile(&ck, EngineOpts::default());
    let sampling = SamplingConfig { temperature: 0.0, top_k: 5, top_p: 0.5, seed: 7 };
    let coord = Coordinator::new(cfg(ck, 0, sampling, DEFAULT_MAX_SESSIONS));
    let sc = coord.session_client().unwrap();
    let h = std::thread::spawn(move || {
        let p = toks(5, 3);
        sc.open("g").unwrap();
        let g = sc.turn("g", p.clone(), 4).unwrap();
        (p, g.tokens)
    });
    let _ = run_within(coord, 120);
    let (p, got) = h.join().unwrap();
    assert_eq!(got, greedy_reference(&model, &p, 4));
}

#[test]
fn fork_and_revert_are_bit_exact_and_books_balance() {
    for page in [0usize, 4] {
        let coord =
            Coordinator::new(cfg(ck(Arch::Opt, 0xF0F0), page, SamplingConfig::default(), DEFAULT_MAX_SESSIONS));
        let sc = coord.session_client().unwrap();
        let h = std::thread::spawn(move || {
            let p1 = toks(4, 4);
            let p2 = toks(3, 5);

            sc.open("src").unwrap();
            sc.turn("src", p1, 3).unwrap(); // history now 7 tokens
            sc.fork("src", "dst").unwrap();
            assert_eq!(sc.tokens("src").unwrap(), sc.tokens("dst").unwrap());

            // the fork answers the same delta identically to the original
            let g_src = sc.turn("src", p2.clone(), 2).unwrap();
            let g_dst = sc.turn("dst", p2.clone(), 2).unwrap();
            assert_eq!(g_src.tokens, g_dst.tokens, "page={page}: fork must not change the tokens");

            // revert src to the pre-delta position and replay: bit-exact
            let hist = sc.revert("src", 7).unwrap();
            assert_eq!(hist.len(), 7);
            let g_again = sc.turn("src", p2, 2).unwrap();
            assert_eq!(g_again.tokens, g_src.tokens, "page={page}: replay after revert drifted");

            // the max_new == 1 fast path commits too (12 + 1 + 1 <= 16)
            let g_one = sc.turn("src", toks(1, 6), 1).unwrap();
            assert_eq!(g_one.tokens.len(), 1);

            sc.close("src").unwrap();
            sc.close("dst").unwrap();
        });
        let report = run_within(coord, 120);
        h.join().unwrap();
        assert_eq!(report.sessions_active, 0);
        assert_books_balance(&report);
    }
}

/// `max_sessions: 1` with two interleaved dialogs: every idle commit
/// evicts the other session's cache, every next turn restores it by
/// re-prefilling the committed history — and the tokens still match the
/// greedy reference exactly.
#[test]
fn lru_eviction_and_restore_are_transparent() {
    for page in [0usize, 4] {
        let ck = ck(Arch::Llama, 0xCAFE);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let coord = Coordinator::new(cfg(ck, page, SamplingConfig::default(), 1));
        let sc = coord.session_client().unwrap();
        let h = std::thread::spawn(move || {
            let mut hists: Vec<Vec<u16>> = vec![toks(4, 7), toks(4, 8)];
            sc.open("s0").unwrap();
            sc.open("s1").unwrap();
            let mut got: Vec<Vec<Vec<u16>>> = vec![Vec::new(), Vec::new()];
            for round in 0..2 {
                for s in 0..2usize {
                    let delta = if round == 0 { hists[s].clone() } else { toks(3, 9 + s) };
                    let id = format!("s{s}");
                    let g = sc.turn(&id, delta.clone(), 3).unwrap();
                    if round > 0 {
                        hists[s].extend_from_slice(&delta);
                    }
                    got[s].push(g.tokens.clone());
                    hists[s].extend_from_slice(&g.tokens);
                }
            }
            (hists, got)
        });
        let report = run_within(coord, 120);
        let (hists, got) = h.join().unwrap();
        for s in 0..2usize {
            // replay each dialog as fresh full-prefill greedy references
            let mut hist = hists[s][..4].to_vec();
            let r1 = greedy_reference(&model, &hist, 3);
            assert_eq!(got[s][0], r1, "page={page} s{s} turn 1");
            hist.extend_from_slice(&r1);
            hist.extend_from_slice(&toks(3, 9 + s));
            let r2 = greedy_reference(&model, &hist, 3);
            assert_eq!(got[s][1], r2, "page={page} s{s} turn 2: restore must be transparent");
        }
        assert_eq!(report.sessions_active, 2, "eviction drops caches, not sessions");
        assert!(
            report.sessions_evicted >= 1,
            "page={page}: a 1-cache cap over 2 dialogs must evict (got {})",
            report.sessions_evicted
        );
        assert!(
            report.session_restores >= 1,
            "page={page}: an evicted dialog's next turn must count a restore (got {})",
            report.session_restores
        );
        assert_books_balance(&report);
    }
}

fn solo_sampled_run(ck: &Checkpoint, sampling: SamplingConfig) -> Vec<Vec<u16>> {
    let coord = Coordinator::new(cfg(ck.clone(), 0, sampling, DEFAULT_MAX_SESSIONS));
    let gc = coord.gen_client().unwrap();
    let h = std::thread::spawn(move || {
        (0..4).map(|i| gc.generate(toks(5, 20 + i), 6).unwrap().tokens).collect::<Vec<_>>()
    });
    let _ = run_within(coord, 120);
    h.join().unwrap()
}

/// Seeded sampling is (a) reproducible across runs and (b) invariant to
/// batch composition: four prompts served strictly one at a time draw
/// the same tokens as the same four packed into one decode batch.
#[test]
fn seeded_sampling_is_reproducible_and_batch_invariant() {
    let ck = ck(Arch::Opt, 0xD1CE);
    let sampling = SamplingConfig { temperature: 0.9, top_k: 12, top_p: 0.95, seed: 1234 };

    let solo = solo_sampled_run(&ck, sampling);
    assert_eq!(solo, solo_sampled_run(&ck, sampling), "same seed, same tokens, every run");

    // now all four in flight together before the loop starts
    let coord = Coordinator::new(cfg(ck, 0, sampling, DEFAULT_MAX_SESSIONS));
    let mut handles = Vec::new();
    for i in 0..4 {
        let gc = coord.gen_client().unwrap();
        handles.push(std::thread::spawn(move || gc.generate(toks(5, 20 + i), 6).unwrap().tokens));
    }
    std::thread::sleep(Duration::from_millis(300));
    let report = run_within(coord, 120);
    let batched: Vec<Vec<u16>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(batched, solo, "batch composition must not change sampled tokens");
    assert!(report.mean_batch_size > 1.0, "the batched leg must actually batch");
}

#[test]
fn turn_stream_emits_each_token_then_done() {
    let coord =
        Coordinator::new(cfg(ck(Arch::Opt, 0x57AB), 0, SamplingConfig::default(), DEFAULT_MAX_SESSIONS));
    let sc = coord.session_client().unwrap();
    let h = std::thread::spawn(move || {
        sc.open("live").unwrap();
        let ticket = sc.turn_stream("live", toks(5, 30), 4).unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        for event in ticket.iter() {
            match event {
                TurnEvent::Token(t) => {
                    assert!(done.is_none(), "no Token may follow Done");
                    streamed.push(t);
                }
                TurnEvent::Done(r) => {
                    assert!(done.is_none(), "exactly one Done per turn");
                    done = Some(r);
                }
            }
        }
        let g = done.expect("stream must end with Done").expect("turn must succeed");
        assert_eq!(streamed, g.tokens, "streamed tokens must equal the final result");
        assert_eq!(streamed.len(), 4);
    });
    let report = run_within(coord, 120);
    h.join().unwrap();
    assert_eq!(report.streamed_tokens, 4);
}

#[test]
fn typed_session_errors() {
    let coord =
        Coordinator::new(cfg(ck(Arch::Opt, 0xE44), 0, SamplingConfig::default(), DEFAULT_MAX_SESSIONS));
    let sc = coord.session_client().unwrap();
    let h = std::thread::spawn(move || {
        assert!(matches!(
            sc.turn("ghost", toks(3, 40), 2),
            Err(ServeError::SessionNotFound(ref id)) if id == "ghost"
        ));
        assert!(matches!(sc.close("ghost"), Err(ServeError::SessionNotFound(_))));
        assert!(matches!(sc.tokens("ghost"), Err(ServeError::SessionNotFound(_))));

        sc.open("chat").unwrap();
        assert!(matches!(
            sc.open("chat"),
            Err(ServeError::DuplicateSession(ref id)) if id == "chat"
        ));
        assert!(matches!(
            sc.fork("chat", "chat"),
            Err(ServeError::DuplicateSession(_))
        ));

        // an empty delta has nothing to prefill: typed Invalid, session stays usable
        assert!(matches!(sc.turn("chat", Vec::new(), 2), Err(ServeError::Invalid(_))));
        sc.turn("chat", toks(3, 41), 2).unwrap();
        sc.close("chat").unwrap();
    });
    let report = run_within(coord, 120);
    h.join().unwrap();
    assert_eq!(report.sessions_active, 0);
}
