//! The packed+LoRC correctness contract — the serving-side half of the
//! paper's third contribution (`Ŵ + E₁E₂` low-rank compensation):
//!
//! 1. A [`CompiledModel`] compiled from the quantized sidecar (codes +
//!    LoRC factors) with `WeightLayout::Packed` produces logits
//!    **bit-identical** to the dense plan — and the reference `Engine` —
//!    over the LoRC-*folded* effective checkpoint, across both
//!    architectures, FP4/INT4 weight formats, every scale constraint
//!    (none/M1/M2), ranks 2 and 8, FP8 and F16 factor storage, and every
//!    execution path (full-window forward, chunked prefill, `decode_step`,
//!    and KV-batched `decode_step_batch`).
//! 2. The memory claim: with rank-8 FP8 factors, the packed+LoRC plan's
//!    resident linear-weight bytes stay ≤ 1/5 of the dense f32 plan (and
//!    the factor bytes are really accounted — the LoRC'd plan reports more
//!    bytes than the factor-free one).
//! 3. GEMV row-sharding (`--gemv-threads`) changes wall-time, never bits,
//!    with factors attached.

use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

fn cfg(arch: Arch, name: &str, d: usize, heads: usize, ff: usize) -> ModelConfig {
    ModelConfig {
        name: format!("lorc-{name}-{}", arch.name()),
        arch,
        vocab_size: 48,
        d_model: d,
        n_heads: heads,
        n_layers: 2,
        d_ff: ff,
        max_seq: 12,
    }
}

fn assert_bit_identical(
    a: &zeroquant_fp::tensor::Matrix,
    b: &zeroquant_fp::tensor::Matrix,
    what: &str,
) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} dense={x} packed={y}");
    }
}

/// Quantize `ck` under (`scheme`, `constraint`, LoRC `rank`/`ffmt`), then
/// require the packed+LoRC plan to reproduce the dense effective-checkpoint
/// plan (and the reference engine) bit-for-bit on full-window forwards.
fn check(
    ck: &Checkpoint,
    scheme: &str,
    constraint: ScaleConstraint,
    rank: usize,
    ffmt: NumericFormat,
    what: &str,
) {
    let recipe = QuantRecipe::builder(Scheme::parse(scheme).unwrap())
        .constraint(constraint)
        .lorc(LorcConfig { rank, factor_format: ffmt })
        .group_size(16) // several groups per row even at toy dims
        .use_gptq(false) // RTN: the codes are the point, not the solver
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(ck, &[], &recipe).unwrap();
    assert!(!stack.sidecar.is_empty(), "{what}: sidecar missing");
    assert!(stack.sidecar.has_lorc(), "{what}: factors missing from sidecar");

    let qck = &stack.checkpoint;
    let opts = EngineOpts::with_act(recipe.scheme.activation);
    let dense = stack.compile_dense();
    let packed = stack.compile();

    let mut rng = Rng::seeded(0x10BC);
    let mut ds = dense.scratch();
    let mut ps = packed.scratch();
    let vocab = ck.config.vocab_size;
    for seq in [1usize, ck.config.max_seq] {
        let tokens: Vec<u16> = (0..seq).map(|_| rng.below(vocab) as u16).collect();
        let want = dense.forward(&tokens, &mut ds).clone();
        let got = packed.forward(&tokens, &mut ps);
        assert_bit_identical(&want, got, &format!("{what} seq={seq}"));
        // and the reference engine over the folded checkpoint agrees
        let reference = Engine::with_opts(qck, opts).forward(&tokens);
        assert_bit_identical(&reference, got, &format!("{what} seq={seq} vs engine"));
    }
}

#[test]
fn lorc_packed_plan_bit_identical_across_the_grid() {
    // both archs × FP4/INT4 × none/M1/M2 × rank {2, 8} × FP8/F16 factors
    for arch in [Arch::Opt, Arch::Llama] {
        let mut rng = Rng::seeded(0x10C0 + arch as u64);
        let ck = Checkpoint::random(&cfg(arch, "grid", 24, 3, 48), &mut rng);
        for scheme in ["w4a8-fp-fp", "w4a8-int-int"] {
            for constraint in [
                ScaleConstraint::None,
                ScaleConstraint::M1,
                ScaleConstraint::M2 { rows: 4 },
            ] {
                for rank in [2usize, 8] {
                    for ffmt in [NumericFormat::FP8_E4M3, NumericFormat::F16] {
                        let what = format!(
                            "{arch:?} {scheme} {} r{rank} {}",
                            constraint.label(),
                            ffmt.name()
                        );
                        check(&ck, scheme, constraint, rank, ffmt, &what);
                    }
                }
            }
        }
    }
}

#[test]
fn lorc_packed_plan_bit_identical_with_gptq_codes_and_odd_dims() {
    // GPTQ codes + odd hidden dims (trailing-nibble rows) compose with the
    // factors like everything else
    for arch in [Arch::Opt, Arch::Llama] {
        let mut rng = Rng::seeded(0x10C9 + arch as u64);
        let ck = Checkpoint::random(&cfg(arch, "odd", 25, 5, 49), &mut rng);
        let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .constraint(ScaleConstraint::M1)
            .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
            .group_size(16)
            .packed(1)
            .build()
            .unwrap();
        let calib: Vec<Vec<u16>> =
            (0..3).map(|c| (0..8).map(|t| ((c * 7 + t) % 48) as u16).collect()).collect();
        let stack = ServingStack::build(&ck, &calib, &recipe).unwrap();
        let dense = stack.compile_dense();
        let packed = stack.compile();
        let tokens: Vec<u16> = (0..10).map(|i| (i * 5 % 48) as u16).collect();
        let mut ds = dense.scratch();
        let mut ps = packed.scratch();
        let want = dense.forward(&tokens, &mut ds).clone();
        let got = packed.forward(&tokens, &mut ps);
        assert_bit_identical(&want, got, &format!("{arch:?} gptq odd-dims"));
    }
}

#[test]
fn lorc_packed_decode_paths_match_dense_decode() {
    // chunked prefill + decode_step + decode_step_batch through the
    // packed+LoRC layout match the dense plan token for token, bit for bit
    for (arch, ffmt) in [(Arch::Llama, NumericFormat::FP8_E4M3), (Arch::Opt, NumericFormat::F16)] {
        let mut rng = Rng::seeded(0xDEC1 + arch as u64);
        let ck = Checkpoint::random(&cfg(arch, "decode", 24, 3, 48), &mut rng);
        let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .constraint(ScaleConstraint::M2 { rows: 8 })
            .lorc(LorcConfig { rank: 8, factor_format: ffmt })
            .use_gptq(false)
            .packed(1)
            .build()
            .unwrap();
        let stack = ServingStack::build(&ck, &[], &recipe).unwrap();
        let dense = stack.compile_dense();
        let packed = stack.compile();

        let window: Vec<u16> = (0..10).map(|i| (i * 7 % 48) as u16).collect();
        let mut ds = dense.scratch();
        let mut ps = packed.scratch();
        let mut dc = dense.kv_cache();
        let mut pc = packed.kv_cache();
        // chunked prefill: two chunks of the same sequence
        let a = dense.prefill(&window[..3], &mut dc, &mut ds).clone();
        let b = packed.prefill(&window[..3], &mut pc, &mut ps);
        assert_bit_identical(&a, b, &format!("{arch:?} prefill chunk 1"));
        let a = dense.prefill(&window[3..6], &mut dc, &mut ds).clone();
        let b = packed.prefill(&window[3..6], &mut pc, &mut ps);
        assert_bit_identical(&a, b, &format!("{arch:?} prefill chunk 2"));
        for (t, &tok) in window[6..].iter().enumerate() {
            let a = dense.decode_step(tok, &mut dc, &mut ds).clone();
            let b = packed.decode_step(tok, &mut pc, &mut ps);
            assert_bit_identical(&a, b, &format!("{arch:?} decode step {t}"));
        }
        // continuous batching: two sequences interleaved
        let mut dcs = vec![dense.kv_cache(), dense.kv_cache()];
        let mut pcs = vec![packed.kv_cache(), packed.kv_cache()];
        for (c, p) in dcs.iter_mut().zip(pcs.iter_mut()) {
            dense.prefill(&window[..3], c, &mut ds);
            packed.prefill(&window[..3], p, &mut ps);
        }
        let a = dense.decode_step_batch(&[window[3], window[4]], &mut dcs, &mut ds).clone();
        let b = packed.decode_step_batch(&[window[3], window[4]], &mut pcs, &mut ps);
        assert_bit_identical(&a, b, &format!("{arch:?} batched decode"));
    }
}

#[test]
fn sharded_lorc_plan_matches_inline() {
    let mut rng = Rng::seeded(0x54A3);
    let ck = Checkpoint::random(&cfg(Arch::Opt, "shard", 24, 3, 48), &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
        .use_gptq(false)
        .packed(1)
        .build()
        .unwrap();
    let sharded_recipe = {
        let mut r = recipe.clone();
        r.weights = zeroquant_fp::engine::WeightLayout::Packed { threads: 3 };
        r.validate().unwrap();
        r
    };
    let stack = ServingStack::build(&ck, &[], &recipe).unwrap();
    let solo = stack.compile();
    let sharded = stack.with_recipe(&sharded_recipe).unwrap().compile();
    let tokens: Vec<u16> = (0..8).map(|i| (i * 5 % 48) as u16).collect();
    assert_bit_identical(
        &solo.forward_alloc(&tokens),
        &sharded.forward_alloc(&tokens),
        "lorc threads=3",
    );
}

#[test]
fn lorc_packed_weights_fit_in_a_fifth_of_dense() {
    // The acceptance bound: rank-8 FP8 factors on top of packed W4 codes
    // keep resident linear-weight bytes ≤ 1/5 of the dense f32 plan. Dims
    // large enough to amortize per-group scales the way real models do
    // (one layer keeps the debug-mode SVD cost down; the ratio is
    // per-layer anyway).
    let mut rng = Rng::seeded(0x51FE);
    let mem_cfg = ModelConfig {
        name: "lorc-mem".into(),
        arch: Arch::Opt,
        vocab_size: 48,
        d_model: 96,
        n_heads: 4,
        n_layers: 1,
        d_ff: 384,
        max_seq: 12,
    };
    let ck = Checkpoint::random(&mem_cfg, &mut rng);
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .lorc(LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 })
        .group_size(64)
        .use_gptq(false)
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(&ck, &[], &recipe).unwrap();
    let dense = stack.compile_dense();
    let packed = stack.compile();
    let (db, pb) = (dense.linear_weight_bytes(), packed.linear_weight_bytes());
    assert!(pb > 0 && db > 0);
    assert!(
        pb * 5 <= db,
        "packed+LoRC linear weights {pb} B must be ≤ 1/5 of dense {db} B"
    );
    // the factors really are accounted: a factor-free packed plan of the
    // same codes is smaller by at least the factor code bytes
    let plain = {
        let mut r = recipe.clone();
        r.lorc = None;
        r.validate().unwrap();
        r
    };
    let packed_plain = ServingStack::build(&ck, &[], &plain).unwrap().compile();
    let lorc_b: usize = stack.report.layers.iter().map(|l| l.lorc_bytes).sum();
    assert!(lorc_b > 0);
    assert!(
        pb >= packed_plain.linear_weight_bytes() + lorc_b,
        "factor bytes must show up in linear_weight_bytes: {pb} vs {} + {lorc_b}",
        packed_plain.linear_weight_bytes()
    );
}
