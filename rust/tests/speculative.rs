//! Self-speculative decoding: the exact-greedy-parity gate (ISSUE 9's
//! tentpole invariant).
//!
//! 1. For every draft/target recipe pair, both architectures, and
//!    `k ∈ {1, 2, 4}`, speculative greedy decode is **token-for-token
//!    identical** to target-only greedy decode — on ring KV and on paged
//!    KV. The draft plan may only change how fast tokens commit, never
//!    which tokens.
//! 2. The parity holds for *arbitrary* drafts: an adversarial draft
//!    compiled from a completely different checkpoint (guaranteed
//!    mid-stream rejections and rollbacks) still yields the exact target
//!    stream.
//! 3. KV rollback at paged-page boundaries: truncating to an exact page
//!    edge frees the trailing pages, truncating mid-page keeps the
//!    partial page, the pool books (`free + resident + leaked == total`)
//!    balance throughout, and decode regrown over the truncated tail is
//!    bit-identical to a fresh cache.
//! 4. The same parity end to end through the serving stack: a coordinator
//!    with `recipe.speculate` set returns exactly the target-only token
//!    streams, with the `spec_*` report counters accounting the rounds.

use zeroquant_fp::coordinator::{ServeReport, ServingStack};
use zeroquant_fp::engine::{EngineOpts, KernelTier};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::speculate::generate_speculative;
use zeroquant_fp::plan::{argmax, CompiledModel, KvPagePool};
use zeroquant_fp::quant::Scheme;
use zeroquant_fp::recipe::{QuantRecipe, SpeculateConfig};
use zeroquant_fp::rng::Rng;

fn tiny(arch: Arch) -> ModelConfig {
    ModelConfig {
        name: format!("speculative-{}", arch.name()),
        arch,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 48,
    }
}

fn bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

/// Target-only greedy decode — the stream every speculative run must
/// reproduce exactly.
fn greedy(model: &CompiledModel, prompt: &[u16], max_new: usize) -> Vec<u16> {
    let mut s = model.scratch();
    let mut cache = model.kv_cache();
    let logits = model.prefill(prompt, &mut cache, &mut s);
    let mut next = argmax(logits.row(logits.rows - 1)) as u16;
    let mut out = vec![next];
    while out.len() < max_new {
        let row = model.decode_step(next, &mut cache, &mut s);
        next = argmax(row.row(0)) as u16;
        out.push(next);
    }
    out
}

/// Three draft/target plan pairs of one checkpoint, built through the
/// production path (`ServingStack::compile` + `compile_draft`):
/// rank-0 fast draft under the packed W4+LoRC target, fast-tier draft of
/// the same packed W4 codes under the oracle target, and a dense
/// FP8-activation draft under the dense W16 target.
fn recipe_pairs(ck: &Checkpoint) -> Vec<(&'static str, CompiledModel, CompiledModel)> {
    let w4 = Scheme::parse("w4a8-fp-fp").unwrap();
    let mut out = Vec::new();
    {
        let draft = QuantRecipe::builder(w4)
            .group_size(16)
            .use_gptq(false)
            .packed(1)
            .kernels(KernelTier::Fast)
            .build()
            .unwrap();
        let target = QuantRecipe::builder(w4)
            .group_size(16)
            .use_gptq(false)
            .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
            .packed(1)
            .speculate(draft, 4)
            .build()
            .unwrap();
        let stack = ServingStack::build(ck, &[], &target).unwrap();
        out.push((
            "lorc-target<-rank0-fast-draft",
            stack.compile(),
            stack.compile_draft().expect("recipe speculates"),
        ));
    }
    {
        let draft = QuantRecipe::builder(w4)
            .group_size(16)
            .use_gptq(false)
            .packed(1)
            .kernels(KernelTier::Fast)
            .build()
            .unwrap();
        let target = QuantRecipe::builder(w4)
            .group_size(16)
            .use_gptq(false)
            .packed(1)
            .speculate(draft, 4)
            .build()
            .unwrap();
        let stack = ServingStack::build(ck, &[], &target).unwrap();
        out.push((
            "oracle-target<-fast-tier-draft",
            stack.compile(),
            stack.compile_draft().expect("recipe speculates"),
        ));
    }
    {
        let draft = QuantRecipe::builder(w4).group_size(16).use_gptq(false).build().unwrap();
        let mut target = QuantRecipe::preset("w16").unwrap();
        target.speculate = Some(SpeculateConfig { draft: Box::new(draft), k: 4 });
        let stack = ServingStack::build(ck, &[], &target).unwrap();
        out.push((
            "w16-target<-dense-fp8act-draft",
            stack.compile(),
            stack.compile_draft().expect("recipe speculates"),
        ));
    }
    out
}

#[test]
fn speculative_decode_matches_target_only_greedy() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0x5BEC + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let prompt: Vec<u16> = (0..8).map(|_| rng.below(cfg.vocab_size) as u16).collect();
        for (label, target, draft) in recipe_pairs(&ck) {
            let want = greedy(&target, &prompt, 24);
            for k in [1usize, 2, 4] {
                // ring KV
                let mut tc = target.kv_cache();
                let mut dc = draft.kv_cache();
                let (got, stats) =
                    generate_speculative(&target, &draft, &prompt, 24, k, &mut tc, &mut dc, None);
                assert_eq!(got, want, "{label} {} k={k} ring diverged", cfg.name);
                assert!(stats.rounds >= 1, "{label}: no rounds ran");
                assert!(stats.accepted <= stats.drafted);

                // paged KV: 5-position pages (misaligned with every k) from
                // a pool sized for the two caches the sequence carries
                let mut pool = KvPagePool::sized_for(&cfg, 5, 0, None, 2);
                let mut tc = pool.new_cache();
                let mut dc = pool.new_cache();
                let (got, _) = generate_speculative(
                    &target,
                    &draft,
                    &prompt,
                    24,
                    k,
                    &mut tc,
                    &mut dc,
                    Some(&mut pool),
                );
                assert_eq!(got, want, "{label} {} k={k} paged diverged", cfg.name);
                // rollback books: each cache holds exactly the pages its
                // committed length needs, and release returns everything
                assert_eq!(tc.pages_held(), pool.pages_for(tc.len()), "{label}: target pages");
                assert_eq!(dc.pages_held(), pool.pages_for(dc.len()), "{label}: draft pages");
                pool.release(&mut tc);
                pool.release(&mut dc);
                assert_eq!(pool.free_pages(), pool.total_pages(), "{label}: pages leaked");
                assert_eq!(pool.leaked_pages(), 0);
            }
        }
    }
}

#[test]
fn adversarial_draft_from_another_checkpoint_stays_exact() {
    // Exactness must hold for ARBITRARY draft proposals, not just close
    // plans: a draft compiled from an unrelated checkpoint disagrees
    // constantly, forcing the rejection/rollback path mid-stream on
    // nearly every round — and the output must still be the exact target
    // stream.
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xADB0 + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let other = Checkpoint::random(&cfg, &mut rng);
        let target = CompiledModel::compile(&ck, EngineOpts::default());
        let draft = CompiledModel::compile(&other, EngineOpts::default());
        let prompt: Vec<u16> = (0..8).map(|_| rng.below(cfg.vocab_size) as u16).collect();
        let want = greedy(&target, &prompt, 24);
        for k in [1usize, 2, 4] {
            let mut tc = target.kv_cache();
            let mut dc = draft.kv_cache();
            let (got, stats) =
                generate_speculative(&target, &draft, &prompt, 24, k, &mut tc, &mut dc, None);
            assert_eq!(got, want, "{} k={k} ring diverged under adversarial draft", cfg.name);
            assert!(stats.rolled_back > 0, "an unrelated draft must hit the rollback path");

            let mut pool = KvPagePool::sized_for(&cfg, 3, 0, None, 2);
            let mut tc = pool.new_cache();
            let mut dc = pool.new_cache();
            let (got, stats) = generate_speculative(
                &target,
                &draft,
                &prompt,
                24,
                k,
                &mut tc,
                &mut dc,
                Some(&mut pool),
            );
            assert_eq!(got, want, "{} k={k} paged diverged under adversarial draft", cfg.name);
            assert!(stats.rolled_back > 0);
            pool.release(&mut tc);
            pool.release(&mut dc);
            assert_eq!(pool.free_pages(), pool.total_pages());
            assert_eq!(pool.leaked_pages(), 0);
        }
    }
}

#[test]
fn paged_rollback_at_page_boundaries_frees_pages_and_regrows_bit_exact() {
    // The rollback primitive verify_commit leans on, at both boundary
    // cases: truncating to an exact page edge must free the trailing
    // pages, truncating mid-page must keep the partial page, and decode
    // regrown over the truncated tail must be bit-identical to a fresh
    // cache — rollback may not disturb the surviving prefix.
    let cfg = tiny(Arch::Opt);
    let mut rng = Rng::seeded(0xB0DA);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let model = CompiledModel::compile(&ck, EngineOpts::default());
    let window: Vec<u16> = (0..12).map(|_| rng.below(cfg.vocab_size) as u16).collect();
    let mut pool = KvPagePool::new(&cfg, 4, 0, None);
    let total = pool.total_pages();

    // fresh-cache reference rows for positions 6..12
    let reference: Vec<Vec<u32>> = {
        let mut s = model.scratch();
        let mut c = pool.new_cache();
        assert!(pool.reserve(&mut c, 12));
        model.prefill(&window[..6], &mut c, &mut s);
        let rows: Vec<Vec<u32>> = window[6..12]
            .iter()
            .map(|&t| bits(model.decode_step(t, &mut c, &mut s).row(0)))
            .collect();
        pool.release(&mut c);
        rows
    };
    assert_eq!(pool.free_pages(), total);

    let mut s = model.scratch();
    let mut c = pool.new_cache();
    assert!(pool.reserve(&mut c, 12));
    model.prefill(&window, &mut c, &mut s);
    assert_eq!((c.len(), c.pages_held()), (12, 3));

    // exact page edge: 12 -> 8 drops page 3 back to the free list
    pool.truncate(&mut c, 8);
    assert_eq!((c.len(), c.pages_held()), (8, 2));
    assert_eq!(pool.free_pages(), total - 2);
    assert_eq!(pool.free_pages() + pool.resident_pages() + pool.leaked_pages(), total);

    // mid-page: 8 -> 6 keeps the partially-live second page
    pool.truncate(&mut c, 6);
    assert_eq!((c.len(), c.pages_held()), (6, 2));
    assert_eq!(pool.free_pages(), total - 2);
    assert_eq!(pool.free_pages() + pool.resident_pages() + pool.leaked_pages(), total);

    // regrow over the truncated tail: bit-identical to the fresh run
    assert!(pool.reserve(&mut c, 6));
    for (i, &t) in window[6..12].iter().enumerate() {
        let row = bits(model.decode_step(t, &mut c, &mut s).row(0));
        assert_eq!(row, reference[i], "regrown decode row {i} diverged after rollback");
    }
    assert_eq!((c.len(), c.pages_held()), (12, 3));
    pool.release(&mut c);
    assert_eq!(pool.free_pages(), total);
    assert_eq!(pool.leaked_pages(), 0);
}

#[test]
fn coordinator_speculative_serving_matches_target_only_and_counts() {
    let cfg = tiny(Arch::Opt);
    let mut rng = Rng::seeded(0xC0DE);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let w4 = Scheme::parse("w4a8-fp-fp").unwrap();
    let draft = QuantRecipe::builder(w4)
        .group_size(16)
        .use_gptq(false)
        .packed(1)
        .kernels(KernelTier::Fast)
        .build()
        .unwrap();
    let mut target = QuantRecipe::builder(w4)
        .group_size(16)
        .use_gptq(false)
        .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::FP8_E4M3 })
        .packed(1)
        .speculate(draft, 4)
        .build()
        .unwrap();
    target.max_batch = 2;
    target.max_wait_ms = 0;

    let prompts: Vec<Vec<u16>> =
        (0..6).map(|i| (0..8).map(|j| ((i * 17 + j * 5) % 48) as u16).collect()).collect();

    // identical traffic through one recipe: 3 clients x 2 generations
    let run = |r: &QuantRecipe| -> (Vec<Vec<u16>>, ServeReport) {
        let coord = ServingStack::build(&ck, &[], r).unwrap().coordinator();
        let mut handles = Vec::new();
        for chunk in prompts.chunks(2) {
            let client = coord.gen_client().unwrap();
            let mine = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                mine.into_iter()
                    .map(|p| client.generate(p, 12).unwrap().tokens)
                    .collect::<Vec<Vec<u16>>>()
            }));
        }
        let report = coord.run().unwrap();
        let mut outs = Vec::new();
        for h in handles {
            outs.extend(h.join().unwrap());
        }
        (outs, report)
    };

    let mut base = target.clone();
    base.speculate = None;
    let (want, base_report) = run(&base);
    assert_eq!(base_report.spec_rounds, 0);
    assert_eq!(base_report.spec_fallbacks, 0);

    let (got, report) = run(&target);
    assert_eq!(got, want, "speculative serving changed the token streams (ring KV)");
    assert!(report.spec_rounds > 0, "speculation never engaged");
    assert!(report.spec_accepted <= report.spec_drafted);
    assert_eq!(report.spec_fallbacks, 0, "ring serving has no reserve failures");
    assert!((0.0..=1.0).contains(&report.spec_acceptance_rate()));
    assert!(report.spec_tokens_per_round() >= 1.0, "every round commits at least one token");

    let mut paged = target.clone();
    paged.kv_page_positions = 5;
    let (got, preport) = run(&paged);
    assert_eq!(got, want, "speculative serving changed the token streams (paged KV)");
    assert!(preport.spec_rounds > 0);
}
