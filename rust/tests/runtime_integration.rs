//! PJRT runtime integration: these tests require `make artifacts` to have
//! run (they skip gracefully otherwise, so `cargo test` stays green on a
//! fresh clone before the build pipeline).

use std::path::{Path, PathBuf};

use zeroquant_fp::engine::EngineOpts;
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::model::Checkpoint;
use zeroquant_fp::quant::ActQuantConfig;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime;

fn artifacts_dir() -> Option<PathBuf> {
    if !runtime::PJRT_AVAILABLE {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("score_selfcheck_a16.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn selfcheck_parity_engine_vs_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    runtime::selfcheck_impl(&dir).expect("selfcheck must pass");
}

#[test]
fn hlo_scorer_batching_invariance() {
    // padded final batch and different batching must give identical totals
    let Some(dir) = artifacts_dir() else { return };
    let cfg = runtime::selfcheck_config();
    let mut rng = Rng::seeded(31337);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let opts = EngineOpts::with_act(NumericFormat::F16);
    let path = dir.join("score_selfcheck_a16.hlo.txt");
    let scorer = runtime::HloScorer::load(&path, 2, cfg.max_seq).unwrap();
    let weights = scorer.upload_weights(&ck).unwrap();
    // 5 windows: exercises a padded final batch (5 = 2+2+1)
    let toks: Vec<u16> = (0..cfg.max_seq * 5)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    let r1 = scorer.ppl_with(&weights, &toks).unwrap();
    let eng = zeroquant_fp::eval::perplexity(&ck, opts, &toks, cfg.max_seq);
    assert_eq!(r1.tokens, eng.tokens);
    let rel = (r1.ppl() - eng.ppl()).abs() / eng.ppl();
    assert!(rel < 2e-3, "hlo={} engine={}", r1.ppl(), eng.ppl());
}

#[test]
fn weight_upload_roundtrip_changes_scores() {
    // two different checkpoints through the same executable give different
    // nll -> weights are really parameters, not baked constants.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = runtime::selfcheck_config();
    let mut rng = Rng::seeded(555);
    let ck1 = Checkpoint::random(&cfg, &mut rng);
    let ck2 = Checkpoint::random(&cfg, &mut rng);
    let path = dir.join("score_selfcheck_a16.hlo.txt");
    let scorer = runtime::HloScorer::load(&path, 2, cfg.max_seq).unwrap();
    let w1 = scorer.upload_weights(&ck1).unwrap();
    let w2 = scorer.upload_weights(&ck2).unwrap();
    let toks: Vec<u16> = (0..cfg.max_seq * 2)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    let n1 = scorer.score_batch(&toks, &w1).unwrap();
    let n2 = scorer.score_batch(&toks, &w2).unwrap();
    assert_ne!(n1, n2);
}

#[test]
fn qmatmul_artifact_matches_rust_quant_semantics() {
    // the Pallas fused kernel, loaded and run from rust, must agree with
    // the rust-side dequant + tokenwise-quant + matmul composition.
    let Some(dir) = artifacts_dir() else { return };
    let (m, k, n, g) = (64usize, 256usize, 128usize, 64usize);
    let path = dir.join(format!("qmatmul_m{m}_k{k}_n{n}_g{g}.hlo.txt"));
    if !path.exists() {
        eprintln!("SKIP: qmatmul artifact missing");
        return;
    }
    let art = runtime::QMatmulArtifact::load(&path, m, k, n, k / g).unwrap();
    let mut rng = Rng::seeded(2024);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let codes: Vec<i32> = (0..n * k).map(|_| rng.below(16) as i32).collect();
    let scales: Vec<f32> = (0..n * (k / g)).map(|_| rng.uniform_f32(0.01, 0.1)).collect();
    let y = art.run(&x, &codes, &scales).unwrap();
    assert_eq!(y.len(), m * n);

    // rust-side reference
    use zeroquant_fp::formats::FpFormat;
    use zeroquant_fp::quant::fake_quant_tokenwise;
    use zeroquant_fp::tensor::Matrix;
    let mut xm = Matrix::from_vec(m, k, x);
    fake_quant_tokenwise(
        &mut xm,
        &ActQuantConfig::new(NumericFormat::FP8_E4M3),
    );
    let mut wm = Matrix::zeros(n, k);
    for r in 0..n {
        for c in 0..k {
            let code = codes[r * k + c] as u16;
            let scale = scales[r * (k / g) + c / g];
            *wm.at_mut(r, c) = FpFormat::E2M1.decode(code) * scale;
        }
    }
    let want = xm.matmul_t(&wm);
    let mut max_diff = 0.0f32;
    for (a, b) in y.iter().zip(&want.data) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-3, "max diff {max_diff}");
}

#[test]
fn coordinator_serves_batches() {
    // dynamic batching end to end: client threads feed the queue, the PJRT
    // loop runs on this (test) thread.
    let Some(dir) = artifacts_dir() else { return };
    use zeroquant_fp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, ScoreBackend};
    let fam = zeroquant_fp::model::ModelConfig::family(zeroquant_fp::model::Arch::Opt);
    let (mcfg, _) = &fam[0];
    let art = dir.join(runtime::score_artifact_name(mcfg, "a16"));
    if !art.exists() {
        eprintln!("SKIP: family artifacts missing");
        return;
    }
    let mut rng = Rng::seeded(888);
    let ck = Checkpoint::random(mcfg, &mut rng);
    let seq = ck.config.max_seq;
    let coord = Coordinator::new(CoordinatorConfig {
        backend: ScoreBackend::Pjrt { artifacts: dir.clone() },
        ck: ck.clone(),
        opts: EngineOpts::default(),
        policy: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(4) },
        kv_quant: None,
        sidecar: None,
        queue_depth: zeroquant_fp::coordinator::DEFAULT_QUEUE_DEPTH,
        deadline: None,
        faults: None,
        speculate: None,
        kv_page_positions: 0,
        kv_budget_bytes: 0,
        sampling: zeroquant_fp::coordinator::SamplingConfig::default(),
        max_sessions: zeroquant_fp::coordinator::DEFAULT_MAX_SESSIONS,
    });
    let mut handles = Vec::new();
    for c in 0..3 {
        let cl = coord.client().unwrap();
        let mut r = Rng::seeded(c as u64);
        let windows: Vec<Vec<u16>> = (0..6)
            .map(|_| (0..seq).map(|_| r.below(ck.config.vocab_size) as u16).collect())
            .collect();
        handles.push(std::thread::spawn(move || {
            windows
                .into_iter()
                .map(|w| cl.score(w).unwrap())
                .collect::<Vec<f32>>()
        }));
    }
    let report = coord.run().unwrap();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    assert_eq!(all.len(), 18);
    assert!(all.iter().all(|v| v.is_finite() && *v > 0.0));
    assert_eq!(report.requests, 18);
    assert!(report.batches <= 18);
    assert!(report.mean_batch_size >= 1.0);
}
