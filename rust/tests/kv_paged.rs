//! The paged-KV contract (ISSUE 8's tentpole gate):
//!
//! 1. A block-paged cache checked out of a [`KvPagePool`] produces logits
//!    **bit-identical** to the contiguous-ring cache token for token —
//!    across both architectures, exact and FP8-quantized KV, every page
//!    size (including degenerate 1-position pages and one page spanning
//!    the whole ring), prompt/decode splits landing exactly on / one
//!    before / one after a page boundary, chunked prefill whose chunks
//!    straddle pages, and incremental page-at-a-time reservation (the
//!    coordinator's decode pattern).
//! 2. Pages recycle: release returns them to the free list and a reused
//!    page serves a fresh sequence bit-identically — stale rows from the
//!    previous tenant are invisible. Quarantined caches leak exactly
//!    their own pages; the books (`free + resident + leaked == total`)
//!    balance at every step.
//! 3. Under a byte budget too small for the offered load, the
//!    coordinator preempts the youngest sequence, requeues it, and every
//!    client still receives the bit-exact greedy tokens — preemption is
//!    invisible in the response, visible only in the report counters.

use std::sync::mpsc::sync_channel;
use std::time::Duration;

use zeroquant_fp::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Generated, SamplingConfig, ScoreBackend,
    ServeReport, DEFAULT_MAX_SESSIONS,
};
use zeroquant_fp::engine::EngineOpts;
use zeroquant_fp::formats::FpFormat;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::{argmax, CompiledModel, KvCache};
use zeroquant_fp::rng::Rng;

fn tiny(arch: Arch) -> ModelConfig {
    ModelConfig {
        name: format!("kv-paged-{}", arch.name()),
        arch,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 12,
    }
}

fn bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

fn random_window(len: usize, vocab: usize, rng: &mut Rng) -> Vec<u16> {
    (0..len).map(|_| rng.below(vocab) as u16).collect()
}

/// Run `window` as `prefill(window[..split])` + one `decode_step` per
/// remaining token through `cache`, returning the bit pattern of every
/// produced logits row. The cache must already have capacity for the
/// whole window (ring, or paged with an up-front reservation).
fn rows_via(
    model: &CompiledModel,
    cache: &mut KvCache,
    window: &[u16],
    split: usize,
) -> Vec<Vec<u32>> {
    let mut s = model.scratch();
    let mut out = Vec::with_capacity(window.len());
    let pre = model.prefill(&window[..split], cache, &mut s);
    assert_eq!(pre.rows, split);
    for t in 0..split {
        out.push(bits(pre.row(t)));
    }
    for &tok in &window[split..] {
        out.push(bits(model.decode_step(tok, cache, &mut s).row(0)));
    }
    assert_eq!(cache.len(), window.len());
    out
}

fn ring_cache(model: &CompiledModel, quant: Option<FpFormat>) -> KvCache {
    match quant {
        None => model.kv_cache(),
        Some(f) => model.kv_cache_quantized(f),
    }
}

/// The headline gate: every (arch × KV format × page size × boundary
/// split) cell of the matrix, paged vs ring, bit for bit. Splits are
/// chosen to land exactly on, one before, and one after a page boundary.
#[test]
fn paged_decode_bit_identical_to_ring_across_formats_and_page_sizes() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xFA6ED + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        for quant in [None, Some(FpFormat::E4M3), Some(FpFormat::E5M2)] {
            for p in [1usize, 3, 4, cfg.max_seq] {
                // splits around the first page boundary, plus the two ends
                let mut splits = vec![1, p.max(2) - 1, p, p + 1, window.len()];
                splits.retain(|s| (1..=window.len()).contains(s));
                splits.dedup();
                for &split in &splits {
                    let what = format!(
                        "{arch:?} kv={:?} page={p} split={split}",
                        quant.map(|f| f.name())
                    );
                    let mut ring = ring_cache(&model, quant);
                    let expect = rows_via(&model, &mut ring, &window, split);
                    let mut pool = model.kv_page_pool(p, 0, quant);
                    let mut cache = pool.new_cache();
                    assert!(pool.reserve(&mut cache, window.len()), "{what}: reserve");
                    assert_eq!(cache.pages_held(), pool.pages_for(window.len()), "{what}");
                    let got = rows_via(&model, &mut cache, &window, split);
                    assert_eq!(got, expect, "{what}: paged logits differ from ring");
                    pool.release(&mut cache);
                    assert_eq!(pool.free_pages(), pool.total_pages(), "{what}: release");
                }
            }
        }
    }
}

/// The coordinator never reserves the whole window up front: it reserves
/// the prompt at admission and then one position at a time as decode
/// fills each page. That incremental pattern must be bit-identical to
/// the up-front reservation, and resident pages must track exactly
/// `pages_for(live positions)` at every step.
#[test]
fn incremental_page_reserve_matches_upfront_reservation() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0x1CE + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        let split = 5usize;
        let p = 3usize;

        let mut up_pool = model.kv_page_pool(p, 0, None);
        let mut up = up_pool.new_cache();
        assert!(up_pool.reserve(&mut up, window.len()));
        let expect = rows_via(&model, &mut up, &window, split);

        let mut pool = model.kv_page_pool(p, 0, None);
        let mut cache = pool.new_cache();
        let mut s = model.scratch();
        assert!(pool.reserve(&mut cache, split));
        assert_eq!(pool.resident_pages(), pool.pages_for(split));
        let pre = model.prefill(&window[..split], &mut cache, &mut s);
        let mut got: Vec<Vec<u32>> = (0..split).map(|t| bits(pre.row(t))).collect();
        for &tok in &window[split..] {
            // a no-op while the tail page has room, a one-page checkout
            // when it does not — exactly the coordinator's pre-step call
            assert!(pool.reserve(&mut cache, 1), "{arch:?}: step reserve");
            got.push(bits(model.decode_step(tok, &mut cache, &mut s).row(0)));
            assert_eq!(pool.resident_pages(), pool.pages_for(cache.len()), "{arch:?}");
        }
        assert_eq!(got, expect, "{arch:?}: incremental reserve changed the bits");
        pool.release(&mut cache);
        assert_eq!(pool.resident_pages(), 0);
    }
}

/// Chunked prefill whose chunk boundaries straddle page boundaries
/// (chunks [3,4,3,2] over 4-position pages: boundaries 3/7/10 against
/// page edges 4/8) — bit-identical to the full-recompute forward.
#[test]
fn chunked_prefill_straddling_page_boundaries_is_bit_identical() {
    for arch in [Arch::Opt, Arch::Llama] {
        let cfg = tiny(arch);
        let mut rng = Rng::seeded(0xC41C + arch as u64);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let window = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
        let full = model.forward(&window, &mut s).clone();
        let mut pool = model.kv_page_pool(4, 0, None);
        let mut cache = pool.new_cache();
        let mut done = 0usize;
        for chunk in [3usize, 4, 3, 2] {
            assert!(pool.reserve(&mut cache, chunk));
            let pre = model.prefill(&window[done..done + chunk], &mut cache, &mut s);
            for t in 0..chunk {
                assert_eq!(
                    bits(pre.row(t)),
                    bits(full.row(done + t)),
                    "{arch:?}: chunked paged row {}",
                    done + t
                );
            }
            done += chunk;
        }
        assert_eq!(cache.len(), cfg.max_seq);
        assert_eq!(cache.pages_held(), pool.pages_for(cfg.max_seq));
    }
}

/// Pages recycle through the free list, a recycled page serves a fresh
/// sequence bit-identically, and a quarantined cache leaks exactly its
/// own pages — with the accounting identity holding throughout.
#[test]
fn pages_recycle_and_quarantine_leaks_only_its_own() {
    let cfg = tiny(Arch::Opt);
    let mut rng = Rng::seeded(0x2EC7C1E);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let model = CompiledModel::compile(&ck, EngineOpts::default());
    let first = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);
    let second = random_window(cfg.max_seq, cfg.vocab_size, &mut rng);

    let mut pool = model.kv_page_pool(3, 0, None);
    assert_eq!(pool.total_pages(), pool.pages_for(cfg.max_seq), "budget 0 = one full ring");

    // tenant A fills every page, then leaves
    let mut a = pool.new_cache();
    assert!(pool.reserve(&mut a, first.len()));
    rows_via(&model, &mut a, &first, 4);
    assert_eq!(pool.free_pages(), 0);
    pool.release(&mut a);
    assert_eq!(pool.free_pages(), pool.total_pages());
    assert_eq!(pool.peak_resident_pages(), pool.total_pages());

    // tenant B through the recycled pages must match a fresh ring
    let mut ring = model.kv_cache();
    let expect = rows_via(&model, &mut ring, &second, 7);
    let mut b = pool.new_cache();
    assert!(pool.reserve(&mut b, second.len()));
    let got = rows_via(&model, &mut b, &second, 7);
    assert_eq!(got, expect, "recycled pages leaked the previous tenant's rows");
    pool.release(&mut b);

    // a quarantined cache leaks exactly the pages it held
    let mut poisoned = pool.new_cache();
    assert!(pool.reserve(&mut poisoned, 2)); // one 3-position page
    poisoned.quarantine();
    pool.release(&mut poisoned);
    assert_eq!(pool.leaked_pages(), 1);
    assert_eq!(pool.resident_pages(), 0);
    assert_eq!(
        pool.free_pages() + pool.resident_pages() + pool.leaked_pages(),
        pool.total_pages(),
        "the books must balance after a leak"
    );
    // the leak shrinks what the pool can ever serve again
    assert!(!pool.can_reserve(cfg.max_seq));
    assert!(pool.can_reserve(3 * (pool.total_pages() - 1)));
}

// ---- coordinator-level preemption ------------------------------------

fn ck16() -> Checkpoint {
    let cfg = ModelConfig {
        name: "kv-paged-serve".into(),
        arch: Arch::Opt,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 16,
    };
    let mut rng = Rng::seeded(0xD0D0);
    Checkpoint::random(&cfg, &mut rng)
}

fn paged_cfg(ck: Checkpoint, page: usize, budget: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        backend: ScoreBackend::Compiled,
        ck,
        opts: EngineOpts::default(),
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::ZERO },
        kv_quant: None,
        sidecar: None,
        queue_depth: 64,
        deadline: None,
        faults: None,
        speculate: None,
        kv_page_positions: page,
        kv_budget_bytes: budget,
        sampling: SamplingConfig::default(),
        max_sessions: DEFAULT_MAX_SESSIONS,
    }
}

fn run_within(coord: Coordinator, secs: u64) -> ServeReport {
    let (tx, rx) = sync_channel(1);
    let h = std::thread::spawn(move || {
        let _ = tx.send(coord.run());
    });
    let report = rx
        .recv_timeout(Duration::from_secs(secs))
        .expect("serving loop must terminate within the watchdog timeout")
        .expect("serving loop must return a report, not an error");
    h.join().unwrap();
    report
}

fn greedy_reference(model: &CompiledModel, prompt: &[u16], max_new: usize) -> Vec<u16> {
    let mut scratch = model.scratch();
    let mut cache = model.kv_cache();
    let mut out = Vec::with_capacity(max_new);
    let logits = model.prefill(prompt, &mut cache, &mut scratch);
    let mut tok = argmax(logits.row(prompt.len() - 1)) as u16;
    out.push(tok);
    for _ in 1..max_new {
        let logits = model.decode_step(tok, &mut cache, &mut scratch);
        tok = argmax(logits.row(0)) as u16;
        out.push(tok);
    }
    out
}

fn prompt_for(i: usize) -> Vec<u16> {
    (0..5).map(|k| ((i * 11 + k * 3) % 48) as u16).collect()
}

/// Run six 5-token-prompt / 6-new-token generations through a paged
/// coordinator, all enqueued before the loop starts so admission sees
/// them together. Returns (per-request tokens, report).
fn serve_six(ck: &Checkpoint, budget: usize) -> (Vec<Vec<u16>>, ServeReport) {
    let coord = Coordinator::new(paged_cfg(ck.clone(), 4, budget));
    let mut handles = Vec::new();
    for i in 0..6usize {
        let client = coord.gen_client().unwrap();
        handles.push(std::thread::spawn(move || client.generate(prompt_for(i), 6)));
    }
    // let every submission land in the (deep enough) queue before the
    // loop starts, so at least two sequences are always in flight and a
    // too-small pool must preempt rather than serialize
    std::thread::sleep(Duration::from_millis(300));
    let report = run_within(coord, 60);
    let tokens = handles
        .into_iter()
        .map(|h| {
            let Generated { tokens, prompt_len, .. } =
                h.join().unwrap().expect("paged serving must answer Ok, not shed");
            assert_eq!(prompt_len, 5);
            tokens
        })
        .collect();
    (tokens, report)
}

/// A 4-page budget against three concurrent sequences that each grow to
/// 11 positions (3 pages): the pool runs dry mid-decode, the youngest
/// sequence is evicted and requeued, and *every* client still gets the
/// bit-exact greedy tokens — then the same traffic under the auto
/// (ring-equivalent) budget finishes preemption-free with identical bits.
#[test]
fn preemption_under_tiny_budget_is_bit_identical_and_balanced() {
    let ck = ck16();
    let reference = CompiledModel::compile(&ck, EngineOpts::default());
    // n_layers × {K,V} × page positions × d_model × sizeof(f32)
    let page_bytes = 2 * 2 * 4 * 24 * 4;

    let (tokens, report) = serve_six(&ck, 4 * page_bytes);
    for (i, toks) in tokens.iter().enumerate() {
        assert_eq!(
            *toks,
            greedy_reference(&reference, &prompt_for(i), 6),
            "request {i}: preemption must not change the tokens"
        );
    }
    assert_eq!(report.requests, 6);
    assert_eq!(report.gen_requests, 6, "requeues must not double-count first attempts");
    assert!(report.kv_preemptions > 0, "a 4-page pool against 9 pages of demand must preempt");
    assert_eq!(
        report.kv_requeues, report.kv_preemptions,
        "every preempted sequence re-enters flight exactly once per eviction"
    );
    assert_eq!(report.kv_pages_total, 4);
    assert_eq!(report.kv_pool_bytes, 4 * page_bytes);
    assert_eq!(
        report.kv_pages_free + report.kv_pages_resident + report.kv_pages_leaked,
        report.kv_pages_total,
        "the books must balance at drain"
    );
    assert_eq!(report.kv_pages_resident, 0, "drain must return every page");
    assert_eq!(report.kv_pages_leaked, 0, "no panics, so no quarantine leaks");
    assert!(report.kv_pages_peak <= report.kv_pages_total);
    // the loop samples resident bytes at phase boundaries while the pool
    // tracks its page high-water exactly, so sampled ≤ exact
    assert!(report.kv_peak_bytes > 0);
    assert!(report.kv_peak_bytes <= report.kv_pages_peak * page_bytes);

    // control: auto budget sizes the pool to the ring plan's bound, so
    // the identical traffic must finish without a single preemption
    let (easy_tokens, easy) = serve_six(&ck, 0);
    assert_eq!(easy_tokens, tokens, "budget pressure must be invisible in the tokens");
    assert_eq!(easy.kv_preemptions, 0, "auto budget must never preempt");
    assert_eq!(easy.kv_requeues, 0);
    assert!(easy.kv_pages_total > 4, "auto budget covers max_active full rings");
}
