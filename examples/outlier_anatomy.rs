//! Anatomy of the activation-outlier problem (the paper's Section 2 /
//! Figure 1 narrative, interactively): inject outliers of growing strength
//! into a trained model and watch INT8 activation quantization collapse
//! while FP8 shrugs.
//!
//! ```bash
//! make ckpt
//! cargo run --release --example outlier_anatomy [-- <model-name>]
//! ```

use std::path::Path;

use zeroquant_fp::engine::{ActivationCapture, Engine, EngineOpts, LinearSite};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::model::{inject_outliers, Checkpoint, ModelConfig, OutlierSpec};
use zeroquant_fp::rng::Rng;

fn main() -> zeroquant_fp::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("opt-s");
    let (cfg, _) = ModelConfig::by_name(name)
        .ok_or_else(|| zeroquant_fp::anyhow!("unknown model {name}"))?;
    let base = Checkpoint::load(Path::new(&format!("ckpt/{}.zqckpt", cfg.name)))
        .map_err(|e| zeroquant_fp::anyhow!("ckpt/{}.zqckpt: {e} (run `make ckpt`)", cfg.name))?;

    let eval = zeroquant_fp::data::Corpus::new(zeroquant_fp::data::CorpusKind::C4)
        .generate(cfg.max_seq * 16, 5);

    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "alpha", "fc2 peak/rms", "W16A16", "W16A8-INT", "W16A8-FP8", "INT/FP gap"
    );
    for alpha in [1.0f32, 4.0, 16.0, 64.0, 256.0] {
        let mut ck = base.clone();
        ck.config.name = cfg.name.clone();
        let mut rng = Rng::seeded(0xA11CE);
        inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);

        // activation stats at the fc2 input (the paper's worst offender)
        let engine = Engine::new(&ck);
        let mut cap = ActivationCapture::default();
        engine.forward_observed(&eval[..cfg.max_seq], &mut |s, x| cap.record(s, x));
        let peak = cap.peak_to_rms(LinearSite::Fc2);

        let ppl = |fmt: NumericFormat| {
            zeroquant_fp::eval::perplexity(&ck, EngineOpts::with_act(fmt), &eval, cfg.max_seq)
            .ppl()
        };
        let p16 = ppl(NumericFormat::F16);
        let pint = ppl(NumericFormat::INT8);
        let pfp = ppl(NumericFormat::FP8_E4M3);
        println!(
            "{:<8} {:>12.1} {:>12.3} {:>12.3} {:>14.3} {:>13.3}x",
            alpha,
            peak,
            p16,
            pint,
            pfp,
            (pint - p16).max(1e-9) / (pfp - p16).max(1e-9)
        );
    }
    println!("\n(the paper's Table 1 column-by-column: as outliers emerge, INT8\n\
              activation ppl blows up while FP8 tracks W16A16 — and W16A16\n\
              itself is invariant because the injection is function-preserving)");
    Ok(())
}
