//! Quickstart: the ZeroQuant-FP numeric stack in two minutes, no external
//! files needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks through (1) the FP8/FP4 vs INT8/INT4 codecs on outlier-skewed
//! data (the paper's Figure 2 intuition), (2) FGQ weight quantization with
//! GPTQ on a synthetic layer, (3) LoRC error compensation, and (4) the
//! power-of-2 scale constraints M1/M2.

use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
use zeroquant_fp::lorc::{LorcConfig, LorcFactors};
use zeroquant_fp::quant::{
    quantize_weight_rtn, ScaleConstraint, WeightQuantConfig,
};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::Matrix;

fn main() {
    let mut rng = Rng::seeded(1234);

    // ---------------------------------------------------------------- 1 --
    println!("== 1. formats on outlier-skewed data (Figure 2 intuition) ==");
    let mut data: Vec<f32> = (0..255).map(|_| rng.normal_f32() * 0.05).collect();
    data.push(12.0); // the outlier
    for fmt in [
        NumericFormat::INT8,
        NumericFormat::FP8_E4M3,
        NumericFormat::FP8_E5M2,
        NumericFormat::INT4,
        NumericFormat::FP4_E2M1,
        NumericFormat::FP4_E3M0,
    ] {
        println!("  {:<12} quant MSE {:.3e}", fmt.name(), fmt.quant_mse(&data));
    }
    println!("  -> FP formats spend precision near zero, where the data lives.\n");

    // ---------------------------------------------------------------- 2 --
    println!("== 2. FGQ weight quantization: RTN vs GPTQ (FP4 E2M1) ==");
    let w = Matrix::randn(128, 256, 0.05, &mut rng);
    // correlated calibration inputs (what makes GPTQ matter)
    let base = Matrix::randn(512, 64, 1.0, &mut rng);
    let mix = Matrix::randn(64, 256, 0.4, &mut rng);
    let x = base.matmul(&mix);
    let mut acc = HessianAccumulator::new(256);
    acc.add_batch(&x);
    let h = acc.finalize();
    let wcfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(64);

    let rtn = quantize_weight_rtn(&w, &wcfg);
    let gptq = gptq_quantize(&w, &h, &wcfg, &GptqConfig::default()).unwrap();
    let out_err = |q: &zeroquant_fp::quant::QuantizedWeight| {
        let y0 = x.matmul_t(&w);
        let y1 = x.matmul_t(&q.dequantize());
        y0.sub(&y1).fro_norm() / y0.fro_norm()
    };
    println!("  RTN  output rel-err {:.4}", out_err(&rtn));
    println!("  GPTQ output rel-err {:.4}", out_err(&gptq.weight));
    println!(
        "  packed: {} B (fp16 would be {} B, {:.1}x smaller)\n",
        gptq.weight.packed_bytes(),
        w.data.len() * 2,
        w.data.len() as f64 * 2.0 / gptq.weight.packed_bytes() as f64
    );

    // ---------------------------------------------------------------- 3 --
    println!("== 3. LoRC low-rank compensation ==");
    let deq = gptq.weight.dequantize();
    let before = deq.mse(&w);
    for rank in [4, 8, 16] {
        let lorc = LorcFactors::compute(
            &w,
            &deq,
            &LorcConfig { rank, factor_format: NumericFormat::FP8_E4M3 },
        )
        .unwrap();
        let after = lorc.apply(&deq).mse(&w);
        println!(
            "  rank {rank:>2}: weight MSE {before:.3e} -> {after:.3e}  (+{} B)",
            lorc.packed_bytes()
        );
    }
    println!();

    // ---------------------------------------------------------------- 4 --
    println!("== 4. power-of-2 scale constraints (the FP4->FP8 cast) ==");
    for (label, c) in [
        ("none", ScaleConstraint::None),
        ("M1 ", ScaleConstraint::M1),
        ("M2 ", ScaleConstraint::M2 { rows: 32 }),
    ] {
        let q = quantize_weight_rtn(&w, &wcfg.with_constraint(c));
        let pow2 = q
            .scales
            .iter()
            .filter(|&&s| zeroquant_fp::quant::is_pow2(s))
            .count();
        println!(
            "  {label}: weight MSE {:.3e}   scales that are 2^n: {}/{}",
            q.dequantize().mse(&w),
            pow2,
            q.scales.len()
        );
    }
    println!("  -> M1 forces every scale to 2^n; M2 only the intra-group ratios.");
}
