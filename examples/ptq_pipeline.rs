//! The full PTQ pipeline on a trained checkpoint: quantize under several
//! Table-2 schemes and report perplexity on the three corpora.
//!
//! ```bash
//! make ckpt    # once: trains the model family
//! cargo run --release --example ptq_pipeline [-- <model-name> [engine|hlo]]
//! ```
//!
//! Defaults to `opt-m` via the PJRT HLO runtime (falls back to the Rust
//! engine if artifacts are missing).

use std::path::Path;

use zeroquant_fp::data::{read_tokens, CorpusKind};
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{inject_outliers, Checkpoint, ModelConfig, OutlierSpec};
use zeroquant_fp::pipeline::{calibrate_finalized, ptq};
use zeroquant_fp::quant::Scheme;
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

fn main() -> zeroquant_fp::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("opt-m");
    let runtime = args.get(1).map(|s| s.as_str()).unwrap_or("hlo");
    let (cfg, alpha) = ModelConfig::by_name(name)
        .ok_or_else(|| zeroquant_fp::anyhow!("unknown model {name}"))?;

    let mut ck = Checkpoint::load(Path::new(&format!("ckpt/{}.zqckpt", cfg.name)))
        .map_err(|e| zeroquant_fp::anyhow!("ckpt/{}.zqckpt: {e} (run `make ckpt`)", cfg.name))?;
    ck.config.name = cfg.name.clone();
    let mut rng = Rng::seeded(0xA11CE);
    inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);
    println!(
        "model {} ({} params, outlier alpha {alpha}), runtime {runtime}",
        cfg.name,
        cfg.n_params()
    );

    let calib: Vec<Vec<u16>> = read_tokens(Path::new("data/calib.tok"))?
        .chunks_exact(cfg.max_seq)
        .map(|c| c.to_vec())
        .collect();
    println!("calibrating on {} sequences ...", calib.len());
    let hessians = calibrate_finalized(&ck, &calib);

    let eval_ppl =
        |qck: &Checkpoint, recipe: &QuantRecipe| -> zeroquant_fp::error::Result<Vec<f64>> {
            let mut out = Vec::new();
            for kind in CorpusKind::ALL {
                let toks = read_tokens(Path::new(&format!("data/eval_{}.tok", kind.name())))?;
                let r = if runtime == "hlo" {
                    zeroquant_fp::runtime::hlo_perplexity(
                        Path::new("artifacts"),
                        qck,
                        &recipe.engine_opts(),
                        &toks,
                        qck.config.max_seq,
                    )?
                } else {
                    zeroquant_fp::eval::perplexity(
                        qck,
                        recipe.engine_opts(),
                        &toks,
                        qck.config.max_seq,
                    )
                };
                out.push(r.ppl());
            }
            Ok(out)
        };

    println!(
        "\n{:<22} {:>8} {:>8} {:>8} {:>8}  {:>9} {:>8}",
        "scheme", "mean", "wiki", "ptb", "c4", "bytes", "ratio"
    );
    for (label, scheme, lorc) in [
        ("W16A16", "w16a16", false),
        ("W8A8 FP-FP", "w8a8-fp-fp", false),
        ("W4A8 INT-INT", "w4a8-int-int", false),
        ("W4A8 FP-FP", "w4a8-fp-fp", false),
        ("W4A8 FP-FP +LoRC", "w4a8-fp-fp", true),
    ] {
        let mut b = QuantRecipe::builder(Scheme::parse(scheme).unwrap());
        if lorc {
            b = b.lorc(LorcConfig::default());
        }
        let recipe = b.build()?;
        let out = ptq(&ck, &calib, Some(&hessians), &recipe);
        let ppls = eval_ppl(&out.checkpoint, &recipe)?;
        let mean = ppls.iter().sum::<f64>() / 3.0;
        println!(
            "{:<22} {:>8.3} {:>8.3} {:>8.3} {:>8.3}  {:>9} {:>7.2}x",
            label,
            mean,
            ppls[0],
            ppls[1],
            ppls[2],
            out.report.quant_bytes,
            out.report.compression()
        );
    }
    println!("\n(expected shape: FP-FP tracks W16A16; INT-INT degrades with alpha; LoRC helps)");
    Ok(())
}
