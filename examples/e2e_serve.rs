//! END-TO-END DRIVER (DESIGN.md §5 "E2E"): the full three-layer stack on a
//! real workload, proving every layer composes:
//!
//!   trained checkpoint (build-time JAX)            — L2 authoring
//!     → Rust PTQ pipeline (GPTQ → FGQ FP4 → M2 constraint → LoRC)
//!     → PJRT executable from an AOT HLO artifact   — L1/L2 lowered once
//!     → Rust serving coordinator (dynamic batcher) — L3 request path
//!     → batched scoring requests from concurrent clients
//!
//! Reports quality (perplexity parity: Rust engine vs PJRT within 0.2%)
//! and serving latency/throughput. Python is never loaded at runtime.
//!
//! ```bash
//! make build artifacts ckpt
//! cargo run --release --example e2e_serve [-- <model> <n_requests>]
//! ```

use std::path::Path;
use std::time::{Duration, Instant};

use zeroquant_fp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use zeroquant_fp::data::{read_tokens, Corpus, CorpusKind};
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{inject_outliers, Checkpoint, ModelConfig, OutlierSpec};
use zeroquant_fp::pipeline::{quantize_checkpoint, PtqConfig};
use zeroquant_fp::quant::{Scheme, ScaleConstraint};
use zeroquant_fp::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("opt-m");
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let (cfg, alpha) =
        ModelConfig::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;

    // ---- load + outlier surrogate ----------------------------------------
    let mut ck = Checkpoint::load(Path::new(&format!("ckpt/{}.zqckpt", cfg.name)))
        .map_err(|e| anyhow::anyhow!("ckpt/{}.zqckpt: {e} (run `make ckpt`)", cfg.name))?;
    ck.config.name = cfg.name.clone();
    let mut rng = Rng::seeded(0xA11CE);
    inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);
    let seq = ck.config.max_seq;

    // ---- PTQ: the paper's headline configuration -------------------------
    // W4A8 FP-FP + M2 power-of-2 scales + E5M2 cast + LoRC — i.e. the
    // deployable H100 configuration of Section 3, end to end.
    let mut pcfg = PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap())
        .with_constraint(ScaleConstraint::M2 { rows: 32 })
        .with_lorc(LorcConfig::default());
    pcfg.cast_fp4_to_e5m2 = true;
    let calib: Vec<Vec<u16>> = read_tokens(Path::new("data/calib.tok"))?
        .chunks_exact(seq)
        .map(|c| c.to_vec())
        .collect();
    println!("[1/4] quantizing {} under {} ...", cfg.name, pcfg.scheme.name());
    let t0 = Instant::now();
    let (qck, report) = quantize_checkpoint(&ck, &calib, &pcfg);
    println!(
        "      {} tensors in {:.1}s, {:.2}x compression ({} -> {} bytes)",
        report.layers.len(),
        t0.elapsed().as_secs_f64(),
        report.compression(),
        report.fp16_bytes,
        report.quant_bytes
    );

    // ---- quality parity: rust engine vs PJRT -----------------------------
    println!("[2/4] quality: engine vs PJRT parity on eval_c4 ...");
    let eval = read_tokens(Path::new("data/eval_c4.tok"))?;
    let eval = &eval[..(seq * 16).min(eval.len())];
    let r_eng = zeroquant_fp::eval::perplexity(&qck, pcfg.engine_opts(), eval, seq);
    let r_hlo = zeroquant_fp::runtime::hlo_perplexity(
        Path::new("artifacts"),
        &qck,
        &pcfg.engine_opts(),
        eval,
        seq,
    )?;
    let rel = (r_eng.ppl() - r_hlo.ppl()).abs() / r_eng.ppl();
    println!(
        "      engine ppl {:.4} | pjrt ppl {:.4} | rel {:.2e}  {}",
        r_eng.ppl(),
        r_hlo.ppl(),
        rel,
        if rel < 2e-3 { "OK" } else { "MISMATCH" }
    );
    anyhow::ensure!(rel < 2e-3, "engine/PJRT parity failed");

    // ---- serving ----------------------------------------------------------
    println!("[3/4] serving {n_requests} scoring requests through the coordinator ...");
    let coord = Coordinator::new(CoordinatorConfig {
        artifacts: "artifacts".into(),
        ck: qck,
        opts: pcfg.engine_opts(),
        policy: BatchPolicy {
            max_batch: zeroquant_fp::runtime::SCORE_BATCH,
            max_wait: Duration::from_millis(2),
        },
    });
    let corpus = Corpus::new(CorpusKind::C4);
    let stream = corpus.generate(n_requests * seq, 99);
    let windows: Vec<Vec<u16>> = stream.chunks_exact(seq).map(|c| c.to_vec()).collect();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..4usize {
        let client = coord.client();
        let mine: Vec<Vec<u16>> = windows.iter().skip(c).step_by(4).cloned().collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            let mut nll = 0.0f64;
            for w in mine {
                nll += client.score(w)? as f64;
            }
            Ok(nll)
        }));
    }
    // the PJRT serving loop runs on this thread (single-client process rule)
    let report = coord.run()?;
    let mut total_nll = 0.0;
    for h in handles {
        total_nll += h.join().unwrap()?;
    }
    let wall = t0.elapsed();

    // ---- report ------------------------------------------------------------
    println!("[4/4] results");
    report.print();
    let scored = windows.len() * (seq - 1);
    println!(
        "      workload ppl {:.4} over {} tokens | {:.0} tok/s scored",
        (total_nll / scored as f64).exp(),
        scored,
        scored as f64 / wall.as_secs_f64()
    );
    println!("e2e_serve OK");
    Ok(())
}
