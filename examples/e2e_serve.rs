//! END-TO-END DRIVER (DESIGN.md §5 "E2E"): the full stack on a real
//! workload, proving every layer composes:
//!
//!   trained checkpoint (build-time JAX; random fallback on a fresh clone)
//!     → one validated QuantRecipe (the w4a8-fp-m2 preset + LoRC) driving
//!       every stage below through ServingStack
//!     → Rust PTQ pipeline (GPTQ → FGQ FP4 → M2 constraint → LoRC)
//!     → compiled execution plan (prepacked weights, arena, LUT A8)
//!     → Rust serving coordinator (dynamic batcher) — L3 request path
//!     → batched scoring requests from concurrent clients
//!     → continuous-batching generation (prefill + KV-cached decode_step,
//!       sequences joining and leaving mid-flight) served from the
//!       bit-packed W4 plan with the LoRC factors riding along as codes
//!
//! Reports quality (bit-identity of the compiled plan vs the reference
//! engine, plus PJRT parity within 0.2% when artifacts are present),
//! serving latency/throughput, and decode tokens/s — and asserts that
//! coordinator-served generation reproduces a direct greedy decode token
//! for token. Python is never loaded at runtime; the example runs on a
//! completely fresh clone (no `make` required — trained checkpoint,
//! calibration data and PJRT artifacts are all optional).
//!
//! ```bash
//! cargo run --release --example e2e_serve [-- <model> <n_requests>]
//! ```

use std::path::Path;
use std::time::Instant;

use zeroquant_fp::coordinator::{pick_backend, ScoreBackend, ServingStack};
use zeroquant_fp::data::{read_tokens, Corpus, CorpusKind};
use zeroquant_fp::engine::{Engine, WeightLayout};
use zeroquant_fp::error::Result;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{inject_outliers, Checkpoint, ModelConfig, OutlierSpec};
use zeroquant_fp::plan::{argmax, logits_nll};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let name = args.first().map(|s| s.as_str()).unwrap_or("opt-m");
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let (cfg, alpha) = ModelConfig::by_name(name)
        .ok_or_else(|| zeroquant_fp::anyhow!("unknown model {name}"))?;

    // ---- load + outlier surrogate ----------------------------------------
    let mut rng = Rng::seeded(0xA11CE);
    let ckpt_path = format!("ckpt/{}.zqckpt", cfg.name);
    let mut ck = match Checkpoint::load(Path::new(&ckpt_path)) {
        Ok(ck) => ck,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!("[{ckpt_path} missing — using a random checkpoint (run `make ckpt` for the trained one)]");
            Checkpoint::random(&cfg, &mut rng)
        }
        // A present-but-unreadable checkpoint is an error, not a fresh
        // clone: silently substituting random weights would report quality
        // numbers for a model the operator never asked about.
        Err(e) => return Err(zeroquant_fp::anyhow!("{ckpt_path}: {e}")),
    };
    ck.config.name = cfg.name.clone();
    inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);
    let seq = ck.config.max_seq;

    // ---- PTQ: the paper's headline configuration -------------------------
    // W4A8 FP-FP + M2 power-of-2 scales + E5M2 cast + LoRC — i.e. the
    // deployable H100 configuration of Section 3, end to end: the
    // `w4a8-fp-m2` preset with LoRC folded in. One validated recipe drives
    // PTQ, the compiled plan and both coordinators below.
    let recipe = {
        let mut r = QuantRecipe::preset("w4a8-fp-m2")?;
        r.name = "w4a8-fp-m2+lorc".to_string();
        r.lorc = Some(LorcConfig::default());
        r.max_wait_ms = 2;
        r.validate()?;
        r
    };
    // Same PTQ artifacts, bit-packed serving layout — the generation
    // coordinator serves from this one.
    let packed_recipe = {
        let mut r = recipe.clone();
        r.weights = WeightLayout::Packed { threads: 1 };
        r.max_wait_ms = 0;
        r.validate()?;
        r
    };
    let calib: Vec<Vec<u16>> = match read_tokens(Path::new("data/calib.tok")) {
        Ok(t) => t.chunks_exact(seq).map(|c| c.to_vec()).collect(),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!("[data/calib.tok missing — synthesizing a C4-surrogate calibration set]");
            Corpus::new(CorpusKind::C4)
                .generate(16 * seq, 2)
                .chunks_exact(seq)
                .map(|c| c.to_vec())
                .collect()
        }
        Err(e) => return Err(zeroquant_fp::anyhow!("data/calib.tok: {e}")),
    };
    println!(
        "[1/5] quantizing {} under {} (recipe {}) ...",
        cfg.name,
        recipe.scheme.name(),
        recipe.name
    );
    let t0 = Instant::now();
    let stack = ServingStack::build(&ck, &calib, &recipe)?;
    println!(
        "      {} tensors in {:.1}s, {:.2}x compression ({} -> {} bytes)",
        stack.report.layers.len(),
        t0.elapsed().as_secs_f64(),
        stack.report.compression(),
        stack.report.fp16_bytes,
        stack.report.quant_bytes
    );

    // ---- quality: compiled plan must match the reference bit-for-bit -----
    println!("[2/5] quality: compiled plan vs reference engine on eval_c4 ...");
    let eval = match read_tokens(Path::new("data/eval_c4.tok")) {
        // A stream shorter than one window would make every check below
        // vacuous (zero windows -> NaN ppl) — treat it like a missing file.
        Ok(t) if t.len() >= seq => t,
        Ok(t) => {
            println!(
                "[data/eval_c4.tok too short ({} < {seq} tokens) — synthesizing an eval stream]",
                t.len()
            );
            Corpus::new(CorpusKind::C4).generate(seq * 16, 5)
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!("[data/eval_c4.tok missing — synthesizing an eval stream]");
            Corpus::new(CorpusKind::C4).generate(seq * 16, 5)
        }
        Err(e) => return Err(zeroquant_fp::anyhow!("data/eval_c4.tok: {e}")),
    };
    let eval = &eval[..(seq * 16).min(eval.len())];
    let opts = recipe.engine_opts();
    let model = stack.compile();
    let mut scratch = model.scratch();
    let engine = Engine::with_opts(&stack.checkpoint, opts);
    let mut mismatches = 0usize;
    let mut nll_sum = 0.0f64;
    let mut windows = 0usize;
    for window in eval.chunks_exact(seq) {
        let reference = engine.forward(window);
        let compiled = model.forward(window, &mut scratch);
        mismatches += reference
            .data
            .iter()
            .zip(&compiled.data)
            .filter(|(a, b)| a.to_bits() != b.to_bits())
            .count();
        nll_sum += logits_nll(compiled, window);
        windows += 1;
    }
    let ppl = (nll_sum / (windows * (seq - 1)) as f64).exp();
    println!(
        "      {} windows, compiled ppl {:.4}, logit mismatches {}  {}",
        windows,
        ppl,
        mismatches,
        if mismatches == 0 { "BIT-IDENTICAL" } else { "MISMATCH" }
    );
    zeroquant_fp::ensure!(mismatches == 0, "compiled/reference parity failed");

    // optional: PJRT parity when artifacts are present
    let hlo = zeroquant_fp::runtime::hlo_perplexity(
        Path::new("artifacts"),
        &stack.checkpoint,
        &opts,
        eval,
        seq,
    );
    match hlo {
        Ok(r_hlo) => {
            let rel = (ppl - r_hlo.ppl()).abs() / ppl;
            println!(
                "      pjrt ppl {:.4} | rel {:.2e}  {}",
                r_hlo.ppl(),
                rel,
                if rel < 2e-3 { "OK" } else { "MISMATCH" }
            );
            zeroquant_fp::ensure!(rel < 2e-3, "compiled/PJRT parity failed");
        }
        Err(e) => println!("      [pjrt parity skipped: {e}]"),
    }

    // ---- serving: scoring -------------------------------------------------
    let backend = pick_backend(Path::new("artifacts"), &stack.checkpoint, &opts);
    let backend_name = match &backend {
        ScoreBackend::Pjrt { .. } => "pjrt",
        ScoreBackend::Compiled => "compiled plan",
    };
    println!(
        "[3/5] serving {n_requests} scoring requests through the coordinator ({backend_name}) ..."
    );
    // the generation coordinator serves the same PTQ artifacts packed
    let gen_stack = stack.with_recipe(&packed_recipe)?;
    let coord = stack.coordinator_with_backend(backend);
    let corpus = Corpus::new(CorpusKind::C4);
    let stream = corpus.generate(n_requests * seq, 99);
    let windows: Vec<Vec<u16>> = stream.chunks_exact(seq).map(|c| c.to_vec()).collect();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..4usize {
        let client = coord.client()?;
        let mine: Vec<Vec<u16>> = windows.iter().skip(c).step_by(4).cloned().collect();
        handles.push(std::thread::spawn(move || -> Result<f64> {
            let mut nll = 0.0f64;
            for w in mine {
                nll += client.score(w)? as f64;
            }
            Ok(nll)
        }));
    }
    // the serving loop runs on this thread (PJRT single-client process rule)
    let report = coord.run()?;
    let mut total_nll = 0.0;
    for h in handles {
        total_nll += h.join().unwrap()?;
    }
    let wall = t0.elapsed();

    // ---- serving: continuous-batching generation --------------------------
    // Prompts prefill into per-sequence KV caches; every in-flight sequence
    // then advances one token per interleaved decode_step_batch call,
    // joining/leaving mid-flight. Always the compiled plan (the incremental
    // state lives there).
    let n_gen = 24usize.min(windows.len());
    let prompt_len = seq / 2;
    let gen_new = seq / 4;
    if n_gen == 0 {
        // zero-request runs have nothing to prefill or to parity-check
        println!("[4/5] continuous-batching generation skipped (no request windows)");
        println!("[5/5] results");
        report.print();
        println!("e2e_serve OK");
        return Ok(());
    }
    println!(
        "[4/5] continuous-batching generation: {n_gen} requests, {prompt_len}-token \
         prompts, {gen_new} new tokens each (packed W4 + LoRC plan) ..."
    );
    // direct greedy decode of the first prompt — the coordinator must
    // reproduce it token for token (same compiled plan, same argmax)
    let expect_first: Vec<u16> = {
        let mut cache = model.kv_cache();
        let logits = model.prefill(&windows[0][..prompt_len], &mut cache, &mut scratch);
        let mut out = vec![argmax(logits.row(logits.rows - 1)) as u16];
        while out.len() < gen_new {
            let last = *out.last().unwrap();
            let row = model.decode_step(last, &mut cache, &mut scratch);
            out.push(argmax(row.row(0)) as u16);
        }
        out
    };
    // Serve generation from the bit-packed layout with the LoRC factors
    // riding along as codes — the paper's best small-model configuration
    // (W4A8+LoRC) at packed-memory footprint. The greedy-parity assert
    // below still checks against the *dense* plan's direct decode: the
    // packed+LoRC plan is bit-identical to it, so the tokens must match.
    let gen_coord = gen_stack.coordinator();
    let mut gen_handles = Vec::new();
    for c in 0..3usize {
        let client = gen_coord.gen_client()?;
        let mine: Vec<Vec<u16>> = windows
            .iter()
            .take(n_gen)
            .skip(c)
            .step_by(3)
            .map(|w| w[..prompt_len].to_vec())
            .collect();
        gen_handles.push(std::thread::spawn(
            move || -> Result<Vec<zeroquant_fp::coordinator::Generated>> {
                let mut out = Vec::new();
                for p in mine {
                    out.push(client.generate(p, gen_new)?);
                }
                Ok(out)
            },
        ));
    }
    let gen_report = gen_coord.run()?;
    let mut gen_results: Vec<Vec<zeroquant_fp::coordinator::Generated>> = Vec::new();
    for h in gen_handles {
        gen_results.push(h.join().unwrap()?);
    }
    for per_client in &gen_results {
        for g in per_client {
            zeroquant_fp::ensure!(g.tokens.len() == gen_new, "short generation");
        }
    }
    let coord_first = &gen_results[0][0];
    zeroquant_fp::ensure!(
        coord_first.tokens == expect_first,
        "coordinator generation diverged from direct greedy decode"
    );
    println!(
        "      {} sequences, decode {:.0} tok/s aggregate (mean in-flight {:.2})  \
         GREEDY-PARITY OK",
        gen_report.gen_requests,
        gen_report.decode_tok_s(),
        gen_report.mean_decode_batch(),
    );

    // ---- report ------------------------------------------------------------
    println!("[5/5] results");
    report.print();
    let scored = windows.len() * (seq - 1);
    println!(
        "      workload ppl {:.4} over {} tokens | {:.0} tok/s scored",
        (total_nll / scored as f64).exp(),
        scored,
        scored as f64 / wall.as_secs_f64()
    );
    gen_report.print();
    println!("e2e_serve OK");
    Ok(())
}
