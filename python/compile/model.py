"""Layer 2: the decoder-only transformer in JAX — the computational twin of
the Rust engine (rust/src/engine/mod.rs).

Same architecture, same op order, same activation-quantization sites; the
Rust engine is the oracle the lowered HLO is cross-checked against
(`zqfp selfcheck`). The quantized linears receive *effective* weights
(already fake-quantized + LoRC-compensated by the Rust pipeline); the
token-wise activation fake-quant ("a16" | "a8int" | "a8fp") is baked into
the lowered graph per artifact.
"""

import jax
import jax.numpy as jnp

from .kernels import fpq
from .zqckpt import ModelConfig, tensor_schema


def _norm(x, g, b, arch: str, eps: float = 1e-5):
    if arch == "opt":
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
        return (x - mean) / jnp.sqrt(var + eps) * g + b
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def _linear(x, w, b=None):
    y = x @ w.T
    return y if b is None else y + b


def _attention(q, k, v, cfg: ModelConfig):
    b, s, d = q.shape
    h, dh = cfg.n_heads, cfg.head_dim
    qh = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)  # [B,H,S,dh]
    kh = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    vh = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
    return ctx.transpose(0, 2, 1, 3).reshape(b, s, d)


def forward(params: dict, tokens, cfg: ModelConfig, act: str = "a16"):
    """Logits [B, S, vocab] for int32 tokens [B, S].

    `params` maps tensor names (the .zqckpt schema) to 2-D f32 arrays;
    1-row tensors keep their [1, d] shape and broadcast.
    """
    aq = lambda x: fpq.act_fake_quant(x, act)
    x = params["embed"][tokens] + params["pos_embed"][None, : tokens.shape[1], :]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        if cfg.arch == "opt":
            a = _norm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"], "opt")
        else:
            a = _norm(x, params[f"{p}.ln1.g"], None, "llama")
        a = aq(a)
        q = _linear(a, params[f"{p}.attn.q.w"], params[f"{p}.attn.q.b"])
        k = _linear(a, params[f"{p}.attn.k.w"], params[f"{p}.attn.k.b"])
        v = _linear(a, params[f"{p}.attn.v.w"], params[f"{p}.attn.v.b"])
        ctx = _attention(q, k, v, cfg)
        ctx = aq(ctx)
        x = x + _linear(ctx, params[f"{p}.attn.o.w"], params[f"{p}.attn.o.b"])
        if cfg.arch == "opt":
            m = _norm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"], "opt")
            m = aq(m)
            h = jax.nn.relu(_linear(m, params[f"{p}.mlp.fc1.w"], params[f"{p}.mlp.fc1.b"]))
            h = aq(h)
            x = x + _linear(h, params[f"{p}.mlp.fc2.w"], params[f"{p}.mlp.fc2.b"])
        else:
            m = _norm(x, params[f"{p}.ln2.g"], None, "llama")
            m = aq(m)
            g = _linear(m, params[f"{p}.mlp.gate.w"])
            u = _linear(m, params[f"{p}.mlp.up.w"])
            h = jax.nn.silu(g) * u
            h = aq(h)
            x = x + _linear(h, params[f"{p}.mlp.down.w"], params[f"{p}.mlp.down.b"])
    if cfg.arch == "opt":
        x = _norm(x, params["final_norm.g"], params["final_norm.b"], "opt")
    else:
        x = _norm(x, params["final_norm.g"], None, "llama")
    return x @ params["embed"].T  # tied LM head


def nll_sums(params: dict, tokens, cfg: ModelConfig, act: str = "a16"):
    """Per-window teacher-forced NLL sums [B] — the scoring artifact body.

    tokens[b, t] predicts tokens[b, t+1] for t in [0, S-2].
    """
    logits = forward(params, tokens, cfg, act)          # [B, S, V]
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.sum(picked, axis=-1)


def sorted_param_names(cfg: ModelConfig):
    """Byte-sorted tensor names — the artifact parameter order (matches the
    Rust BTreeMap iteration)."""
    return sorted(name for name, _, _ in tensor_schema(cfg))


def make_score_fn(cfg: ModelConfig, act: str):
    """A positional-arg score function ready for jax.jit().lower():
    f(tokens, *weights_sorted_by_name) -> (nll_sums [B],)."""
    names = sorted_param_names(cfg)

    def score(tokens, *weights):
        params = dict(zip(names, weights))
        return (nll_sums(params, tokens, cfg, act),)

    return score


def init_params(cfg: ModelConfig, key):
    """GPT-2-style init, matching Checkpoint::random's structure (values
    differ — training replaces them anyway)."""
    params = {}
    for name, r, c in tensor_schema(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            params[name] = jnp.zeros((r, c), jnp.float32)
        elif name.endswith(".g"):
            params[name] = jnp.ones((r, c), jnp.float32)
        elif name in ("embed", "pos_embed"):
            params[name] = 0.02 * jax.random.normal(sub, (r, c), jnp.float32)
        else:
            std = 0.4 / jnp.sqrt(jnp.float32(cfg.d_model))
            params[name] = std * jax.random.normal(sub, (r, c), jnp.float32)
    return params
