"""Python reader/writer for the `.zqckpt` checkpoint format.

Mirrors rust/src/model/checkpoint.rs byte-for-byte (the Rust doc comment is
the normative spec). Tensors are name-sorted on write so the parameter
order of lowered artifacts matches the Rust BTreeMap iteration order.
"""

import struct
from dataclasses import dataclass

import numpy as np

MAGIC = b"ZQCKPT01"
ARCH_OPT = 0
ARCH_LLAMA = 1


@dataclass
class ModelConfig:
    name: str
    arch: str  # "opt" | "llama"
    vocab_size: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    max_seq: int

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


# The size family — MUST stay in sync with rust/src/model/config.rs
# (ModelConfig::family). Checked indirectly by `zqfp info` / table runs.
def family(arch: str):
    mk = lambda tag, d, h, l: ModelConfig(
        name=f"{arch}-{tag}", arch=arch, vocab_size=512, d_model=d,
        n_heads=h, n_layers=l, d_ff=4 * d, max_seq=128)
    return [
        (mk("xs", 64, 2, 2), 1.0),
        (mk("s", 96, 4, 3), 32.0),
        (mk("m", 128, 4, 4), 192.0),
        (mk("l", 192, 6, 4), 768.0),
    ]


def selfcheck_config():
    """Mirror of rust/src/runtime/mod.rs::selfcheck_config."""
    return ModelConfig(name="selfcheck", arch="opt", vocab_size=48,
                       d_model=24, n_heads=3, n_layers=2, d_ff=48, max_seq=16)


def tensor_schema(cfg: ModelConfig):
    """Mirror of Checkpoint::tensor_schema (names and [rows, cols])."""
    d, ff = cfg.d_model, cfg.d_ff
    names = [("embed", cfg.vocab_size, d), ("pos_embed", cfg.max_seq, d)]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        names.append((f"{p}.ln1.g", 1, d))
        if cfg.arch == "opt":
            names.append((f"{p}.ln1.b", 1, d))
        for proj in ["q", "k", "v", "o"]:
            names.append((f"{p}.attn.{proj}.w", d, d))
            names.append((f"{p}.attn.{proj}.b", 1, d))
        names.append((f"{p}.ln2.g", 1, d))
        if cfg.arch == "opt":
            names.append((f"{p}.ln2.b", 1, d))
            names.append((f"{p}.mlp.fc1.w", ff, d))
            names.append((f"{p}.mlp.fc1.b", 1, ff))
            names.append((f"{p}.mlp.fc2.w", d, ff))
            names.append((f"{p}.mlp.fc2.b", 1, d))
        else:
            names.append((f"{p}.mlp.gate.w", ff, d))
            names.append((f"{p}.mlp.up.w", ff, d))
            names.append((f"{p}.mlp.down.w", d, ff))
            names.append((f"{p}.mlp.down.b", 1, d))
    names.append(("final_norm.g", 1, d))
    if cfg.arch == "opt":
        names.append(("final_norm.b", 1, d))
    return names


def save(path, cfg: ModelConfig, tensors: dict):
    """Write a checkpoint. `tensors` maps name -> 2-D float32 array."""
    arch = ARCH_OPT if cfg.arch == "opt" else ARCH_LLAMA
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack(
            "<8I", arch, cfg.vocab_size, cfg.d_model, cfg.n_heads,
            cfg.n_layers, cfg.d_ff, cfg.max_seq, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(np.asarray(tensors[name], np.float32))
            assert arr.ndim == 2, (name, arr.shape)
            f.write(struct.pack("<I", len(name)))
            f.write(name.encode())
            f.write(struct.pack("<II", arr.shape[0], arr.shape[1]))
            f.write(arr.astype("<f4").tobytes())


def load(path):
    """Read a checkpoint -> (ModelConfig, dict name -> np.float32 [r, c])."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC, "bad magic"
    pos = 8
    arch, vocab, d, h, l, ff, ms, n = struct.unpack_from("<8I", data, pos)
    pos += 32
    cfg = ModelConfig(name="loaded", arch="opt" if arch == ARCH_OPT else "llama",
                      vocab_size=vocab, d_model=d, n_heads=h, n_layers=l,
                      d_ff=ff, max_seq=ms)
    tensors = {}
    for _ in range(n):
        (nl,) = struct.unpack_from("<I", data, pos)
        pos += 4
        name = data[pos:pos + nl].decode()
        pos += nl
        r, c = struct.unpack_from("<II", data, pos)
        pos += 8
        arr = np.frombuffer(data, "<f4", r * c, pos).reshape(r, c).copy()
        pos += 4 * r * c
        tensors[name] = arr
    assert pos == len(data), "trailing bytes"
    return cfg, tensors


def read_tokens(path):
    """Read a `.tok` stream (little-endian u16) as an int32 numpy array."""
    return np.fromfile(path, dtype="<u2").astype(np.int32)
