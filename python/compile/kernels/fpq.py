"""Bit-exact jnp mirrors of the Rust numeric-format codecs.

This module is the Layer-1/Layer-2 twin of ``rust/src/formats/``: the same
ExMy floating-point fake-quantizer (round-to-nearest-even, saturating, IEEE
subnormals) and the symmetric INT quantizer, expressed in jnp so it can be
used inside Pallas kernels and jitted/lowered models.

Bit-exactness argument (mirrors the Rust comments): every scaling step is by
a power of two, so ``a / quantum`` is exact in f32, and ``jnp.round`` (which
rounds half to even, like ``f32::round_ties_even``) makes the identical
decision. The scale division ``x / scale`` is performed in f32 on both
sides. See rust/src/formats/exmy.rs and python/tests/test_fpq.py.
"""

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class FpFormat:
    exp_bits: int
    man_bits: int
    bias: int
    inf_reserved: bool = False  # IEEE top-exponent Inf/NaN reservation
    nan_reserved: bool = False  # NVIDIA E4M3: all-ones code is NaN

    @property
    def max_exp_field(self) -> int:
        top = (1 << self.exp_bits) - 1
        return top - 1 if self.inf_reserved else top

    @property
    def max_finite(self) -> float:
        man_max = 2.0 - 2.0 ** (-self.man_bits)
        if self.nan_reserved and self.man_bits > 0:
            man_max -= 2.0 ** (-self.man_bits)
        return man_max * 2.0 ** (self.max_exp_field - self.bias)

    @property
    def min_normal(self) -> float:
        return 2.0 ** (1 - self.bias)

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (1 - self.bias - self.man_bits)

    @property
    def name(self) -> str:
        return f"E{self.exp_bits}M{self.man_bits}"


def ieee(e: int, m: int) -> FpFormat:
    return FpFormat(e, m, (1 << (e - 1)) - 1, inf_reserved=True)


def qtorch(e: int, m: int) -> FpFormat:
    return FpFormat(e, m, (1 << (e - 1)) - 1, inf_reserved=False)


E4M3 = qtorch(4, 3)          # paper default FP8 (max 480, qtorch semantics)
E5M2 = ieee(5, 2)            # cast target (max 57344)
E2M1 = qtorch(2, 1)          # paper default FP4
E3M0 = qtorch(3, 0)          # Table A.1 baseline
E4M3_NV = FpFormat(4, 3, 7, nan_reserved=True)  # H100 variant (max 448)


def fp_quantize(x, fmt: FpFormat):
    """Quantize f32 values to the nearest representable point of ``fmt``.

    Vectorized over any shape; returns f32 holding exactly-representable
    values (fake quantization). RNE, saturating.
    """
    x = jnp.asarray(x, jnp.float32)
    a = jnp.abs(x)
    sign = jnp.where(x < 0, -1.0, 1.0).astype(jnp.float32)
    max_finite = jnp.float32(fmt.max_finite)
    # frexp: a = m * 2^e with m in [0.5, 1)  =>  floor(log2 a) = e - 1
    _, e = jnp.frexp(jnp.where(a == 0, 1.0, a))
    floor_log2 = e - 1
    # ldexp, not exp2: jnp.exp2 is a polynomial approximation on CPU and is
    # NOT exact at integer arguments — ldexp manipulates the exponent field
    # directly and matches the Rust `pow2` bit-for-bit.
    quantum = jnp.ldexp(jnp.float32(1.0), floor_log2 - fmt.man_bits)
    q_normal = jnp.round(a / quantum) * quantum
    q_normal = jnp.minimum(q_normal, max_finite)
    min_sub = jnp.float32(fmt.min_subnormal)
    q_sub = jnp.round(a / min_sub) * min_sub
    q = jnp.where(
        a >= max_finite,
        max_finite,
        jnp.where(a < jnp.float32(fmt.min_normal), q_sub, q_normal),
    )
    return jnp.where(a == 0, jnp.float32(0), sign * q).astype(jnp.float32)


def int_quantize(x, qmax: int):
    """Symmetric integer fake-quant at a given qmax (127 for INT8, 7 for
    INT4) with the scale already divided out: input is ``x / scale``."""
    x = jnp.asarray(x, jnp.float32)
    q = jnp.clip(jnp.round(x), -qmax, qmax)
    return q.astype(jnp.float32)


# --- token-wise activation fake-quant (mirrors quant/activation.rs) --------

def tokenwise_absmax_scale(x, denom: float):
    """Per-row absmax / denom, guarded for all-zero rows."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return jnp.where(absmax > 0, absmax / jnp.float32(denom), jnp.float32(1.0))


def act_fake_quant(x, kind: str):
    """Token-wise activation fake-quant. ``kind`` in {a16, a8int, a8fp}.

    x: [..., tokens, features]; each token row gets a dynamic absmax scale.
    """
    if kind == "a16":
        return x
    if kind == "a8int":
        scale = tokenwise_absmax_scale(x, 127.0)
        return int_quantize(x / scale, 127) * scale
    if kind == "a8fp":
        scale = tokenwise_absmax_scale(x, E4M3.max_finite)
        return fp_quantize(x / scale, E4M3) * scale
    raise ValueError(f"unknown act kind {kind}")


# --- FP4 code decode (for the fused W4A8 kernel) ---------------------------

def decode_codes(codes, fmt: FpFormat):
    """Arithmetic bit-field decode of (sign|exp|man) codes — the in-register
    FP4→FP8 'cast' path. No LUT gather: sign/exponent/mantissa are peeled
    with shifts and recombined with ldexp, mirroring how the H100 cast is a
    pure exponent-field manipulation once scales are powers of two.
    """
    codes = jnp.asarray(codes, jnp.int32)
    man_mask = (1 << fmt.man_bits) - 1
    exp_mask = (1 << fmt.exp_bits) - 1
    m = (codes & man_mask).astype(jnp.float32)
    e = (codes >> fmt.man_bits) & exp_mask
    sign = jnp.where((codes >> (fmt.exp_bits + fmt.man_bits)) & 1 == 1, -1.0, 1.0)
    sub = m * jnp.float32(fmt.min_subnormal)
    frac = 1.0 + m * jnp.float32(2.0 ** (-fmt.man_bits))
    normal = jnp.ldexp(frac, e - fmt.bias)
    return (sign * jnp.where(e == 0, sub, normal)).astype(jnp.float32)


def decode_table(fmt: FpFormat):
    """All 2^bits code values of a (sign|exp|man) format as an f32 array,
    indexed by code — the LUT the qmatmul kernel uses to dequantize."""
    n_bits = 1 + fmt.exp_bits + fmt.man_bits
    vals = []
    for code in range(1 << n_bits):
        man_mask = (1 << fmt.man_bits) - 1
        m = code & man_mask
        e_field = (code >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)
        sign = -1.0 if (code >> (fmt.exp_bits + fmt.man_bits)) & 1 else 1.0
        if e_field == 0:
            mag = m * fmt.min_subnormal
        else:
            mag = (1.0 + m * 2.0 ** (-fmt.man_bits)) * 2.0 ** (e_field - fmt.bias)
        vals.append(sign * mag)
    return jnp.asarray(vals, jnp.float32)
