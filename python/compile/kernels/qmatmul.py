"""Pallas kernel: the fused W4A8 GEMM — the paper's compute hot-spot.

One fused device op performs, per output tile:

  1. token-wise FP8-E4M3 fake-quant of the activation tile (VPU),
  2. FP4-E2M1 decode of the weight codes via a 16-entry LUT plus the FGQ
     group-scale multiply — the in-register FP4→FP8 "cast" the paper's
     power-of-2 scale constraints make a pure exponent shift,
  3. the tile contraction (MXU on real TPU).

TPU mapping (DESIGN.md §3 Hardware-Adaptation): H100 threadblock tiling
becomes a (M/bm, N/bn) Pallas grid; shared-memory staging becomes
BlockSpec-scheduled HBM→VMEM copies; tensor-core WMMA becomes the MXU dot.
The full K dimension rides in VMEM per tile (our K ≤ 768 → ≤ bm·K + bn·K +
bm·bn floats ≈ well under the ~16 MB VMEM budget; §Perf in EXPERIMENTS.md
tabulates footprints per block shape).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so correctness is validated through the interpreter and real
TPU performance is estimated analytically (EXPERIMENTS.md §Perf-TPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fpq


def _qmatmul_kernel(x_ref, codes_ref, scales_ref, o_ref, *, group: int,
                    act_kind: str):
    x = x_ref[...]                      # [bm, K] f32
    codes = codes_ref[...]              # [bn, K] i32
    scales = scales_ref[...]            # [bn, G] f32
    # 1. token-wise activation quant
    xq = fpq.act_fake_quant(x, act_kind)
    # 2. FP4 arithmetic decode + FGQ dequant (the in-register cast path —
    #    bit-field peel + ldexp; no LUT gather, which the image's XLA 0.5.1
    #    cannot round-trip through HLO text when the table is a constant)
    w = fpq.decode_codes(codes, fpq.E2M1)   # [bn, K]
    w = w * jnp.repeat(scales, group, axis=1)
    # 3. contraction (lowers to the MXU on TPU)
    o_ref[...] = jnp.dot(xq, w.T, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("group", "act_kind", "block_m", "block_n"),
)
def qmatmul(x, codes, scales, *, group: int = 64, act_kind: str = "a8fp",
            block_m: int = 32, block_n: int = 32):
    """Fused W4A8 GEMM: ``act_quant(x) @ dequant(codes, scales)ᵀ``.

    x:      [M, K] f32
    codes:  [N, K] int32 (FP4 E2M1 codes in the low 4 bits)
    scales: [N, G] f32, G = K // group
    -> [M, N] f32
    """
    m, k = x.shape
    n, k2 = codes.shape
    g = scales.shape[1]
    assert k == k2 and g * group == k
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0
    kernel = functools.partial(_qmatmul_kernel, group=group, act_kind=act_kind)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, g), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        interpret=True,
    )(x, codes, scales)


def vmem_footprint_bytes(block_m: int, block_n: int, k: int, group: int) -> int:
    """Estimated VMEM bytes per program instance (the §Perf-TPU model):
    activation tile + code tile (i32) + decoded tile + scale tile + output.
    """
    g = k // group
    return 4 * (block_m * k          # x tile f32
                + block_n * k        # codes i32
                + block_n * k        # decoded w f32
                + block_n * g        # scales
                + block_m * block_n) # out tile


def mxu_utilization_estimate(block_m: int, block_n: int, k: int) -> float:
    """Fraction of MXU 128x128x8 issue slots doing useful work for one tile
    contraction — the structural efficiency dial for block-shape choice."""
    pad = lambda v, t: -(-v // t) * t
    useful = block_m * block_n * k
    issued = pad(block_m, 128) * pad(block_n, 128) * pad(k, 8)
    return useful / issued
