"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract: pytest asserts kernel == ref across shapes/dtypes, hypothesis
sweeps the space)."""

import jax.numpy as jnp

from . import fpq


def ref_act_quant(x, kind: str):
    """Token-wise activation fake-quant over the last axis."""
    return fpq.act_fake_quant(x, kind)


def ref_qmatmul(x, codes, scales, *, group: int, act_kind: str = "a8fp",
                wfmt: fpq.FpFormat = fpq.E2M1):
    """The paper's W4A8 GEMM, unfused reference.

    x:      [M, K] f32 activations
    codes:  [N, K] int32 FP4 codes (low 4 bits)
    scales: [N, G] f32 FGQ group scales (G = K / group)
    returns [M, N] f32 = act_quant(x) @ dequant(codes, scales)^T
    """
    m, k = x.shape
    n, k2 = codes.shape
    assert k == k2
    g = scales.shape[1]
    assert g * group == k, (g, group, k)
    w = fpq.decode_codes(codes, wfmt)                  # [N, K]
    w = w * jnp.repeat(scales, group, axis=1)          # FGQ dequant
    xq = fpq.act_fake_quant(x, act_kind)
    return xq @ w.T
