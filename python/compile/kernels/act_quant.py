"""Pallas kernel: token-wise activation fake-quantization.

TPU mapping (DESIGN.md §3): the grid tiles the token axis; each program
instance holds a [block_t, D] tile in VMEM, computes per-token absmax
scales with a VPU row-reduce, and quantizes in registers. interpret=True
(the CPU PJRT plugin cannot execute Mosaic custom-calls); on a real TPU the
same BlockSpec schedule stages HBM→VMEM per tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fpq


def _act_quant_kernel(x_ref, o_ref, *, kind: str):
    x = x_ref[...]
    o_ref[...] = fpq.act_fake_quant(x, kind)


@functools.partial(jax.jit, static_argnames=("kind", "block_t"))
def act_quant(x, kind: str = "a8fp", block_t: int = 8):
    """Token-wise fake-quant of a [T, D] activation matrix."""
    t, d = x.shape
    assert t % block_t == 0, f"T={t} not divisible by block_t={block_t}"
    return pl.pallas_call(
        functools.partial(_act_quant_kernel, kind=kind),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        grid=(t // block_t,),
        in_specs=[pl.BlockSpec((block_t, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        interpret=True,
    )(x)
