"""Build-time trainer: pretrains the synthetic model family on the Rust-
generated corpus mixture and writes `.zqckpt` checkpoints the Rust pipeline
consumes. Pure JAX (no flax/optax offline) — hand-rolled AdamW + cosine
schedule.

Usage:  cd python && python -m compile.pretrain --data ../data --out ../ckpt
        [--arch opt|llama|all] [--steps N] [--batch B] [--log ../ckpt/train_log.txt]
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import zqckpt


def adamw_init(params):
    zeros = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, wd=0.01, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    out_p, out_m, out_v = {}, {}, {}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    for k in params:
        g = grads[k]
        m = b1 * state["m"][k] + (1 - b1) * g
        v = b2 * state["v"][k] + (1 - b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        decay = 0.0 if k.endswith(".b") or k.endswith(".g") else wd
        out_p[k] = params[k] - lr * (update + decay * params[k])
        out_m[k], out_v[k] = m, v
    return out_p, {"m": out_m, "v": out_v, "t": t}


def make_train_step(cfg):
    def loss_fn(params, tokens):
        nll = M.nll_sums(params, tokens, cfg, act="a16")
        return jnp.sum(nll) / (tokens.shape[0] * (tokens.shape[1] - 1))

    @jax.jit
    def step(params, state, tokens, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        # global-norm clip at 1.0
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        scale = jnp.minimum(1.0, 1.0 / (gn + 1e-6))
        grads = {k: g * scale for k, g in grads.items()}
        params, state = adamw_update(params, grads, state, lr)
        return params, state, loss

    return step


def batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n_windows = len(tokens) // seq
    windows = tokens[: n_windows * seq].reshape(n_windows, seq)
    for _ in range(steps):
        idx = rng.integers(0, n_windows, size=batch)
        yield jnp.asarray(windows[idx])


def train_one(cfg, train_tokens, steps, batch, base_lr, log):
    key = jax.random.PRNGKey(hash(cfg.name) & 0x7FFFFFFF)
    params = M.init_params(cfg, key)
    state = adamw_init(params)
    step_fn = make_train_step(cfg)
    t0 = time.time()
    warmup = max(10, steps // 20)
    loss_hist = []
    for i, toks in enumerate(batches(train_tokens, batch, cfg.max_seq, steps, 1234)):
        # cosine with warmup
        if i < warmup:
            lr = base_lr * (i + 1) / warmup
        else:
            prog = (i - warmup) / max(1, steps - warmup)
            lr = base_lr * 0.5 * (1 + np.cos(np.pi * prog))
        params, state, loss = step_fn(params, state, toks, jnp.float32(lr))
        if i % 25 == 0 or i == steps - 1:
            loss_v = float(loss)
            loss_hist.append((i, loss_v))
            msg = (f"[{cfg.name}] step {i:4d}/{steps}  loss {loss_v:.4f}  "
                   f"ppl {np.exp(loss_v):9.2f}  lr {lr:.2e}  "
                   f"{time.time() - t0:6.1f}s")
            print(msg, flush=True)
            log.write(msg + "\n")
            log.flush()
    return params, loss_hist


# per-size step budget (larger models converge per-step faster on this data
# but cost more wall-clock; single-CPU budget)
STEPS = {"xs": 500, "s": 400, "m": 300, "l": 250}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../data")
    ap.add_argument("--out", default="../ckpt")
    ap.add_argument("--arch", default="all", choices=["opt", "llama", "all"])
    ap.add_argument("--steps", type=int, default=0, help="override per-size budget")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--only", default="", help="train only this family tag (e.g. m)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    train_tokens = zqckpt.read_tokens(os.path.join(args.data, "train.tok"))
    print(f"train corpus: {len(train_tokens)} tokens")
    archs = ["opt", "llama"] if args.arch == "all" else [args.arch]
    log_path = os.path.join(args.out, "train_log.txt")
    with open(log_path, "a") as log:
        for arch in archs:
            for cfg, _alpha in zqckpt.family(arch):
                tag = cfg.name.split("-")[-1]
                if args.only and tag != args.only:
                    continue
                out_path = os.path.join(args.out, f"{cfg.name}.zqckpt")
                if os.path.exists(out_path):
                    print(f"{cfg.name}: exists, skipping")
                    continue
                steps = args.steps or STEPS[tag]
                print(f"=== training {cfg.name} "
                      f"(d={cfg.d_model}, L={cfg.n_layers}, {steps} steps) ===")
                params, _ = train_one(cfg, train_tokens, steps, args.batch,
                                      args.lr, log)
                tensors = {k: np.asarray(v) for k, v in params.items()}
                zqckpt.save(out_path, cfg, tensors)
                print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
