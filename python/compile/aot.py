"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text** artifacts.

HLO *text* (not `.serialize()`): the image's xla_extension 0.5.1 rejects
jax≥0.5 serialized protos (64-bit instruction ids); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (the contract in rust/src/runtime/mod.rs):
  score_{arch}_d{d}_l{L}_{act}.hlo.txt   family scoring fns, B=8, S=128
  score_selfcheck_{act}.hlo.txt          miniature parity-check fn, B=2, S=16
  qmatmul_m64_k256_n128_g64.hlo.txt      Pallas fused W4A8 GEMM
  actquant_a8fp_t64_d256.hlo.txt         Pallas token-wise act-quant kernel

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import zqckpt
from .kernels import act_quant as aqk
from .kernels import qmatmul as qmk

SCORE_BATCH = 8
ACTS = ["a16", "a8int", "a8fp"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides array constants as
    # `constant({...})`, which the text *parser* silently reads as zeros —
    # any baked LUT (e.g. the FP4 decode table) would vanish.
    po = xc._xla.HloPrintOptions()
    po.print_large_constants = True
    # no metadata: jax emits source_end_line/... attributes that the 0.5.1
    # text parser rejects.
    po.print_metadata = False
    return comp.as_hlo_module().to_string(po)


def write(path: str, text: str):
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def lower_score(cfg: zqckpt.ModelConfig, act: str, batch: int) -> str:
    score = M.make_score_fn(cfg, act)
    tok_spec = jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.int32)
    w_specs = [
        jax.ShapeDtypeStruct((r, c), jnp.float32)
        for _, r, c in sorted(zqckpt.tensor_schema(cfg))
    ]
    lowered = jax.jit(score).lower(tok_spec, *w_specs)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--family-only", action="store_true",
                    help="skip kernel demo artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # --- family scoring artifacts -----------------------------------------
    for arch in ["opt", "llama"]:
        for cfg, _alpha in zqckpt.family(arch):
            for act in ACTS:
                name = f"score_{arch}_d{cfg.d_model}_l{cfg.n_layers}_{act}.hlo.txt"
                path = os.path.join(args.out, name)
                write(path, lower_score(cfg, act, SCORE_BATCH))

    # --- selfcheck (engine-parity) artifacts -------------------------------
    sc = zqckpt.selfcheck_config()
    for act in ACTS:
        path = os.path.join(args.out, f"score_selfcheck_{act}.hlo.txt")
        write(path, lower_score(sc, act, batch=2))

    if args.family_only:
        return

    # --- Pallas kernel artifacts (interpret=True lowering) ------------------
    m, k, n, g = 64, 256, 128, 64
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    codes = jax.ShapeDtypeStruct((n, k), jnp.int32)
    scales = jax.ShapeDtypeStruct((n, k // g), jnp.float32)

    def qmm(x, codes, scales):
        return (qmk.qmatmul(x, codes, scales, group=g),)

    lowered = jax.jit(qmm).lower(x, codes, scales)
    write(os.path.join(args.out, f"qmatmul_m{m}_k{k}_n{n}_g{g}.hlo.txt"),
          to_hlo_text(lowered))

    t, d = 64, 256
    xs = jax.ShapeDtypeStruct((t, d), jnp.float32)

    def aq(x):
        return (aqk.act_quant(x, kind="a8fp"),)

    lowered = jax.jit(aq).lower(xs)
    write(os.path.join(args.out, f"actquant_a8fp_t{t}_d{d}.hlo.txt"),
          to_hlo_text(lowered))


if __name__ == "__main__":
    main()
