"""L2 model correctness: shapes, causality, activation-quant sites, and the
scoring head. (Parity with the Rust engine is checked by `zqfp selfcheck`
on the lowered artifacts — the stronger cross-layer test.)"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M
from compile import zqckpt


def tiny(arch="opt"):
    return zqckpt.ModelConfig(name="t", arch=arch, vocab_size=48, d_model=24,
                              n_heads=3, n_layers=2, d_ff=48, max_seq=16)


@pytest.mark.parametrize("arch", ["opt", "llama"])
def test_forward_shapes(arch):
    cfg = tiny(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab_size
    logits = M.forward(params, toks, cfg)
    assert logits.shape == (2, 16, 48)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["opt", "llama"])
def test_causality(arch):
    cfg = tiny(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    t1 = jnp.array([[5, 6, 7, 8]], jnp.int32)
    t2 = jnp.array([[5, 6, 7, 40]], jnp.int32)
    l1 = M.forward(params, t1, cfg)
    l2 = M.forward(params, t2, cfg)
    np.testing.assert_array_equal(np.asarray(l1[0, :3]), np.asarray(l2[0, :3]))
    assert not np.allclose(np.asarray(l1[0, 3]), np.asarray(l2[0, 3]))


def test_nll_sums_matches_manual():
    cfg = tiny()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    toks = (jnp.arange(16, dtype=jnp.int32) * 5 % 48).reshape(1, 16)
    nll = M.nll_sums(params, toks, cfg)
    logits = M.forward(params, toks, cfg)
    logp = jax.nn.log_softmax(logits[0, :-1], axis=-1)
    manual = -sum(float(logp[t, int(toks[0, t + 1])]) for t in range(15))
    assert float(nll[0]) == pytest.approx(manual, rel=1e-5)


def test_act_quant_perturbs_but_tracks():
    cfg = tiny()
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    toks = jnp.array([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    base = M.forward(params, toks, cfg, act="a16")
    q8 = M.forward(params, toks, cfg, act="a8fp")
    rel = float(jnp.linalg.norm(base - q8) / jnp.linalg.norm(base))
    # random-init logits are small, so the relative perturbation is noisy;
    # trained models sit well below this (engine test asserts < 0.05).
    assert 0.0 < rel < 0.12


def test_sorted_param_names_matches_schema():
    cfg = tiny()
    names = M.sorted_param_names(cfg)
    assert names == sorted(names)
    assert set(names) == {n for n, _, _ in zqckpt.tensor_schema(cfg)}


def test_score_fn_positional_order():
    cfg = tiny()
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    toks = jnp.zeros((2, 16), jnp.int32)
    score = M.make_score_fn(cfg, "a16")
    weights = [params[n] for n in M.sorted_param_names(cfg)]
    (nll,) = score(toks, *weights)
    direct = M.nll_sums(params, toks, cfg)
    np.testing.assert_allclose(np.asarray(nll), np.asarray(direct), rtol=1e-6)
