"""Checkpoint format: python roundtrip, schema/family agreement, and (when
the binary is present) cross-validation against the Rust reader."""

import os
import subprocess
import tempfile

import numpy as np
import pytest

import jax

from compile import model as M
from compile import zqckpt


def test_roundtrip():
    cfg = zqckpt.selfcheck_config()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tensors = {k: np.asarray(v) for k, v in params.items()}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.zqckpt")
        zqckpt.save(path, cfg, tensors)
        cfg2, tensors2 = zqckpt.load(path)
        assert cfg2.d_model == cfg.d_model
        assert cfg2.arch == cfg.arch
        assert set(tensors2) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(tensors[k], tensors2[k])


def test_schema_counts():
    for arch in ["opt", "llama"]:
        for cfg, alpha in zqckpt.family(arch):
            schema = zqckpt.tensor_schema(cfg)
            names = [n for n, _, _ in schema]
            assert len(names) == len(set(names))
            assert alpha >= 1.0
            # every init param matches schema shape
            params = M.init_params(cfg, jax.random.PRNGKey(1))
            for n, r, c in schema:
                assert params[n].shape == (r, c), n


ZQFP = os.path.join(os.path.dirname(__file__), "..", "..", "target",
                    "release", "zqfp")


@pytest.mark.skipif(not os.path.exists(ZQFP), reason="rust binary not built")
def test_rust_reads_python_checkpoint():
    cfg = zqckpt.selfcheck_config()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    tensors = {k: np.asarray(v) for k, v in params.items()}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.zqckpt")
        zqckpt.save(path, cfg, tensors)
        out = subprocess.run([ZQFP, "info", "--ckpt", path],
                             capture_output=True, text=True, check=True)
        assert "arch=opt" in out.stdout
        assert "d_model=24" in out.stdout
        assert f"tensors={len(tensors)}" in out.stdout
