"""Pallas kernels vs pure-jnp oracle (ref.py): hypothesis sweeps shapes and
block sizes; assert_allclose at f32 tolerance. This is the L1 correctness
contract — the same code paths are lowered into the AOT artifacts."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import act_quant as aqk
from compile.kernels import fpq, qmatmul, ref


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("kind", ["a16", "a8int", "a8fp"])
@pytest.mark.parametrize("t,d,bt", [(8, 32, 8), (32, 64, 8), (16, 128, 4), (64, 256, 16)])
def test_act_quant_matches_ref(kind, t, d, bt):
    x = rand(t * d, t, d) * 3.0
    got = aqk.act_quant(x, kind=kind, block_t=bt)
    want = ref.ref_act_quant(x, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    bt=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([16, 48, 128]),
    kind=st.sampled_from(["a8int", "a8fp"]),
    seed=st.integers(0, 2**16),
)
def test_act_quant_hypothesis(t_blocks, bt, d, kind, seed):
    t = t_blocks * bt
    x = rand(seed, t, d) * 10.0
    got = aqk.act_quant(x, kind=kind, block_t=bt)
    want = ref.ref_act_quant(x, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-5)


def make_qweights(key, n, k, group):
    kk = jax.random.PRNGKey(key)
    codes = jax.random.randint(kk, (n, k), 0, 16, jnp.int32)
    scales = 0.01 + jnp.abs(jax.random.normal(kk, (n, k // group), jnp.float32)) * 0.1
    return codes, scales


@pytest.mark.parametrize("m,k,n,g,bm,bn", [
    (8, 32, 8, 16, 8, 8),
    (16, 64, 32, 32, 8, 16),
    (32, 128, 64, 64, 32, 32),
    (64, 256, 128, 64, 32, 32),
])
def test_qmatmul_matches_ref(m, k, n, g, bm, bn):
    x = rand(m * k, m, k)
    codes, scales = make_qweights(7, n, k, g)
    got = qmatmul.qmatmul(x, codes, scales, group=g, block_m=bm, block_n=bn)
    want = ref.ref_qmatmul(x, codes, scales, group=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    mb=st.integers(1, 3),
    nb=st.integers(1, 3),
    bm=st.sampled_from([4, 8]),
    bn=st.sampled_from([4, 8]),
    kg=st.sampled_from([(32, 16), (64, 32), (64, 64)]),
    act=st.sampled_from(["a16", "a8int", "a8fp"]),
    seed=st.integers(0, 2**16),
)
def test_qmatmul_hypothesis(mb, nb, bm, bn, kg, act, seed):
    k, g = kg
    m, n = mb * bm, nb * bn
    x = rand(seed, m, k) * 2.0
    codes, scales = make_qweights(seed + 1, n, k, g)
    got = qmatmul.qmatmul(x, codes, scales, group=g, act_kind=act,
                          block_m=bm, block_n=bn)
    want = ref.ref_qmatmul(x, codes, scales, group=g, act_kind=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_qmatmul_block_shape_invariance():
    """Block decomposition must not change results (pure data parallel)."""
    m, k, n, g = 32, 64, 32, 32
    x = rand(3, m, k)
    codes, scales = make_qweights(4, n, k, g)
    outs = [
        np.asarray(qmatmul.qmatmul(x, codes, scales, group=g, block_m=bm, block_n=bn))
        for bm, bn in [(8, 8), (16, 32), (32, 16), (32, 32)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=0, atol=2e-5)


def test_vmem_footprint_model():
    b = qmatmul.vmem_footprint_bytes(32, 32, 256, 64)
    # 32*256 + 32*256 + 32*256 + 32*4 + 32*32 floats = 25728 * 4
    assert b == 4 * (3 * 32 * 256 + 32 * 4 + 32 * 32)
    assert b < 16 * 1024 * 1024  # fits VMEM


def test_mxu_estimate_monotone():
    lo = qmatmul.mxu_utilization_estimate(8, 8, 64)
    hi = qmatmul.mxu_utilization_estimate(128, 128, 256)
    assert 0 < lo < hi <= 1.0


def test_e3m0_table_used_by_ref():
    x = rand(11, 8, 32)
    codes, scales = make_qweights(12, 8, 32, 16)
    y1 = ref.ref_qmatmul(x, codes, scales, group=16, wfmt=fpq.E3M0)
    y2 = ref.ref_qmatmul(x, codes, scales, group=16, wfmt=fpq.E2M1)
    assert not np.allclose(np.asarray(y1), np.asarray(y2))
