"""Correctness of the jnp numeric-format codecs against an independent
float64 reference (the same algorithm the Rust side implements), plus
golden values from the paper's format definitions."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import fpq


# --- independent float64 reference (mirrors rust/src/formats/exmy.rs) ------

def ref_quantize(x: float, fmt: fpq.FpFormat) -> float:
    if x == 0 or not np.isfinite(x):
        return 0.0 if x == 0 else x
    a = abs(float(x))
    sign = -1.0 if x < 0 else 1.0
    maxf = fmt.max_finite
    if a >= maxf:
        return sign * maxf
    if a < fmt.min_normal:
        q = fmt.min_subnormal
        # round-half-even on an exactly-representable quotient
        r = np.float64(a) / q
        return sign * float(np.round(r)) * q
    e = int(np.floor(np.log2(a)))
    # guard against log2 boundary error
    if 2.0 ** (e + 1) <= a:
        e += 1
    if 2.0 ** e > a:
        e -= 1
    quantum = 2.0 ** (e - fmt.man_bits)
    r = float(np.round(np.float64(a) / quantum)) * quantum
    return sign * min(r, maxf)


FORMATS = [fpq.E4M3, fpq.E5M2, fpq.E2M1, fpq.E3M0]


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
def test_golden_extremes(fmt):
    golden = {
        "E4M3": (480.0, 2.0 ** -6, 2.0 ** -9),
        "E5M2": (57344.0, 2.0 ** -14, 2.0 ** -16),
        "E2M1": (6.0, 1.0, 0.5),
        "E3M0": (16.0, 0.25, 0.25),
    }[fmt.name]
    assert fmt.max_finite == golden[0]
    assert fmt.min_normal == golden[1]
    assert fmt.min_subnormal == golden[2]


def test_e2m1_value_set():
    xs = np.linspace(-8, 8, 2001, dtype=np.float32)
    q = np.asarray(fpq.fp_quantize(xs, fpq.E2M1))
    assert set(np.abs(q).tolist()) <= {0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0}


def test_rne_ties():
    q = fpq.fp_quantize(jnp.array([1.25, 1.75, 2.5, 3.5, 5.0]), fpq.E2M1)
    assert np.allclose(np.asarray(q), [1.0, 2.0, 2.0, 4.0, 4.0])


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
@settings(max_examples=300, deadline=None)
@given(st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32))
def test_matches_f64_reference(fmt, x):
    got = float(fpq.fp_quantize(jnp.float32(x), fmt))
    want = ref_quantize(np.float32(x), fmt)
    assert got == pytest.approx(want, abs=0.0), (x, got, want)


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f.name)
def test_idempotent(fmt):
    rng = np.random.default_rng(0)
    xs = (rng.standard_normal(512) * fmt.max_finite * 0.3).astype(np.float32)
    q1 = np.asarray(fpq.fp_quantize(xs, fmt))
    q2 = np.asarray(fpq.fp_quantize(q1, fmt))
    np.testing.assert_array_equal(q1, q2)


def test_saturation():
    for fmt in FORMATS:
        q = float(fpq.fp_quantize(jnp.float32(1e30), fmt))
        assert q == fmt.max_finite
        q = float(fpq.fp_quantize(jnp.float32(-1e30), fmt))
        assert q == -fmt.max_finite


def test_int_quantize_rne():
    q = np.asarray(fpq.int_quantize(jnp.array([0.5, 1.5, 2.5, -0.5, 200.0]), 127))
    assert q.tolist() == [0.0, 2.0, 2.0, 0.0, 127.0]


def test_tokenwise_act_quant_outlier_isolation():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((4, 64)).astype(np.float32) * 0.1
    x[3] *= 1000
    q = np.asarray(fpq.act_fake_quant(jnp.asarray(x), "a8fp"))
    # clean rows almost unchanged
    assert np.max(np.abs(q[0] - x[0])) < 0.01
    # outlier row scaled by its own absmax
    assert np.max(np.abs(q[3] - x[3])) < np.max(np.abs(x[3])) * 0.07


def test_decode_table_roundtrip():
    for fmt in [fpq.E2M1, fpq.E3M0]:
        table = np.asarray(fpq.decode_table(fmt))
        assert len(table) == 16
        # every decoded value quantizes to itself
        q = np.asarray(fpq.fp_quantize(jnp.asarray(table), fmt))
        np.testing.assert_array_equal(np.abs(q), np.abs(table))


def test_a16_passthrough():
    x = jnp.array([[1.2345, -9.87]])
    np.testing.assert_array_equal(np.asarray(fpq.act_fake_quant(x, "a16")),
                                  np.asarray(x))
